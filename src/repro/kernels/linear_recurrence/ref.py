"""Pure-jnp oracle for the linear-recurrence kernel.

Computes h_t = a_t * h_{t-1} + b_t along axis 1 via ``associative_scan``
(first-order linear recurrences compose associatively:
(a1,b1) ∘ (a2,b2) = (a1*a2, a2*b1 + b2)).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _combine(left, right):
    a_l, b_l = left
    a_r, b_r = right
    return a_l * a_r, a_r * b_l + b_r


def linear_recurrence(a: jax.Array, b: jax.Array, h0: jax.Array) -> jax.Array:
    """a, b: (B, S, W) fp32; h0: (B, W).  Returns h: (B, S, W)."""
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    # fold the initial state into the first step
    b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))
    _, h = jax.lax.associative_scan(_combine, (a, b), axis=1)
    return h
