"""Pallas TPU linear-recurrence kernel: h_t = a_t * h_{t-1} + b_t.

Used by the RG-LRU (RecurrentGemma) recurrent branch.  TPU-native design:

  * grid = (batch, width_blocks, seq_chunks) — seq innermost/sequential; the
    running state h (one (bw,) fp32 vector per width block) persists in VMEM
    scratch across chunk steps.
  * within a chunk the scan is computed in log2(bs) *vectorized* doubling
    passes over the (bs, bw) tile (Blelloch-style inclusive scan on the
    (a, b) semigroup), not a length-bs sequential loop — the VPU sees wide
    elementwise ops only.
  * the chunk is then closed with h_chunk = A ⊙ h_carry + B where A is the
    inclusive decay product, giving the cross-chunk recurrence.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, h0_ref, o_ref, h_ref, *, block_s: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        h_ref[...] = h0_ref[0].astype(jnp.float32)

    A = a_ref[0].astype(jnp.float32)          # (bs, bw)
    B = b_ref[0].astype(jnp.float32)
    # inclusive scan on the linear-recurrence semigroup via doubling:
    # (A1,B1) o (A2,B2) = (A1*A2, A2*B1 + B2), combining t with t-2^i.
    steps = max(1, int(math.ceil(math.log2(block_s))))
    for i in range(steps):
        shift = 1 << i
        if shift >= block_s:
            break
        A_prev = jnp.concatenate(
            [jnp.ones((shift, A.shape[1]), A.dtype), A[:-shift]], axis=0)
        B_prev = jnp.concatenate(
            [jnp.zeros((shift, B.shape[1]), B.dtype), B[:-shift]], axis=0)
        B = A * B_prev + B
        A = A * A_prev
    h = A * h_ref[...][None, :] + B           # fold in the carry
    o_ref[0] = h.astype(o_ref.dtype)
    h_ref[...] = h[-1]


@functools.partial(jax.jit, static_argnames=("block_s", "block_w", "interpret"))
def linear_recurrence(
    a: jax.Array,      # (B, S, W) decay in (0, 1]
    b: jax.Array,      # (B, S, W) input
    h0: jax.Array,     # (B, W) initial state
    *,
    block_s: int = 256,
    block_w: int = 512,
    interpret: bool = False,
) -> jax.Array:
    Bb, S, W = a.shape
    bs = min(block_s, S)
    bw = min(block_w, W)
    assert S % bs == 0 and W % bw == 0, (S, bs, W, bw)
    grid = (Bb, W // bw, S // bs)
    kernel = functools.partial(_kernel, block_s=bs)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bs, bw), lambda bi, wi, si: (bi, si, wi)),
            pl.BlockSpec((1, bs, bw), lambda bi, wi, si: (bi, si, wi)),
            pl.BlockSpec((1, bw), lambda bi, wi, si: (bi, wi)),
        ],
        out_specs=pl.BlockSpec((1, bs, bw), lambda bi, wi, si: (bi, si, wi)),
        out_shape=jax.ShapeDtypeStruct((Bb, S, W), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bw,), jnp.float32)],
        interpret=interpret,
    )(a, b, h0)
