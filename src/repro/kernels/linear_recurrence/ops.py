"""Jit wrapper for the linear-recurrence kernel.

Forward: Pallas; backward: reference vjp (the recurrence adjoint is itself
a linear recurrence run in reverse — a dedicated bwd kernel is a tracked
perf item).
"""

from __future__ import annotations

import functools

import jax

from repro.kernels.linear_recurrence import ref
from repro.kernels.linear_recurrence.linear_recurrence import (
    linear_recurrence as _pallas,
)


def _pick(n: int, prefs) -> int:
    for b in prefs:
        if n % b == 0:
            return b
    return 1


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _linrec(a, b, h0, interpret):
    bs = _pick(a.shape[1], (256, 128, 64, 32, 16, 8, 4, 2, 1))
    bw = _pick(a.shape[2], (512, 256, 128, 64, 32, 16, 8, 5, 4, 2, 1))
    return _pallas(a, b, h0, block_s=bs, block_w=bw, interpret=interpret)


def _fwd(a, b, h0, interpret):
    return _linrec(a, b, h0, interpret), (a, b, h0)


def _bwd(interpret, res, g):
    a, b, h0 = res
    _, vjp = jax.vjp(ref.linear_recurrence, a, b, h0)
    return vjp(g)


_linrec.defvjp(_fwd, _bwd)


def linear_recurrence(a, b, h0, interpret=False):
    return _linrec(a, b, h0, interpret)
