"""Pallas TPU decode attention: one query token per sequence over a long
(possibly ring-buffered) KV cache, plus the paged (block-pool) variant.

TPU-native design:
  * GQA grouping is exploited for MXU utilization: the G query heads that
    share one kv head are processed together as a (G, D) LHS, so the score
    matmul is (G, D) x (D, bk) instead of G separate vector products.
  * grid = (batch, kv_heads, kv_blocks); kv innermost, online-softmax
    accumulators (G x D in fp32) in VMEM scratch — the split-K structure of
    FlashDecoding mapped onto the sequential-grid + scratch idiom.
  * ring-buffer validity and windowing come from the absolute-position
    tile, same convention as the flash kernel.

``paged_decode_attention`` reuses the same online-softmax body but reads
K/V straight out of a global block pool: the per-sequence block table is a
scalar-prefetch operand (``pltpu.PrefetchScalarGridSpec``), so the BlockSpec
index map resolves logical kv-block ``ki`` of sequence ``b`` to physical
pool block ``table[b, ki]`` before the DMA is issued — no gather/copy of
the cache ever materializes.  Key positions are synthesized from the grid
(``ki * block_size + iota``): gathered index == absolute position, so
causal masking hides the unwritten tail and garbage-block table entries.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30


def _kernel(
    q_pos_ref,                  # (1, 1) int32
    k_pos_ref,                  # (1, bk) int32
    q_ref,                      # (1, 1, G, D)  — G q-heads of this kv head
    k_ref, v_ref,               # (1, bk, 1, D)
    o_ref,                      # (1, 1, G, D)
    acc_ref, m_ref, l_ref,      # VMEM scratch: (G, D), (G, 1), (G, 1) f32
    *,
    window: int,
    softcap: float,
    scale: float,
    num_kv_blocks: int,
):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos = q_pos_ref[0, 0]
    k_pos = k_pos_ref[0]                       # (bk,)
    valid = (k_pos >= 0) & (k_pos <= q_pos)
    if window > 0:
        valid = valid & (q_pos - k_pos < window)

    @pl.when(jnp.any(valid))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)    # (G, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # (bk, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                              # (G, bk)
        if softcap > 0.0:
            s = jnp.tanh(s / softcap) * softcap
        s = jnp.where(valid[None, :], s, NEG_INF)
        m_prev = m_ref[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.where(valid[None, :], jnp.exp(s - m_new[:, None]), 0.0)
        l_ref[:, 0] = l_ref[:, 0] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[:, 0] = m_new

    @pl.when(ki == num_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "softcap", "block_kv", "interpret")
)
def decode_attention(
    q: jax.Array,              # (B, 1, Hq, D)
    k_cache: jax.Array,        # (B, L, Hkv, D)
    v_cache: jax.Array,
    q_positions: jax.Array,    # (B, 1) int32
    k_positions: jax.Array,    # (B, L) int32
    *,
    window: int = 0,
    softcap: float = 0.0,
    block_kv: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, S, Hq, D = q.shape
    assert S == 1, "decode kernel is single-token"
    _, L, Hkv, _ = k_cache.shape
    G = Hq // Hkv
    bk = min(block_kv, L)
    assert L % bk == 0, (L, bk)
    nk = L // bk
    grid = (B, Hkv, nk)
    # view q as (B, 1, Hkv, G, D) via reshape outside the call
    qg = q.reshape(B, 1, Hkv * G, D)

    kernel = functools.partial(
        _kernel, window=window, softcap=softcap,
        scale=1.0 / math.sqrt(D), num_kv_blocks=nk,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, ki: (b, 0)),
            pl.BlockSpec((1, bk), lambda b, h, ki: (b, ki)),
            pl.BlockSpec((1, 1, G, D), lambda b, h, ki: (b, 0, h, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, ki: (b, ki, h, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, ki: (b, ki, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, ki: (b, 0, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 1, Hq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q_positions.astype(jnp.int32), k_positions.astype(jnp.int32),
      qg, k_cache, v_cache)
    return out.reshape(B, 1, Hq, D)


def _paged_kernel(
    bt_ref,                     # scalar-prefetch: (B, nb) int32 block table
    q_pos_ref,                  # (1, 1) int32
    q_ref,                      # (1, 1, G, D)
    k_ref, v_ref,               # (1, bs, 1, D) — physical block via index map
    o_ref,                      # (1, 1, G, D)
    acc_ref, m_ref, l_ref,      # VMEM scratch: (G, D), (G, 1), (G, 1) f32
    *,
    window: int,
    softcap: float,
    scale: float,
    num_kv_blocks: int,
    block_size: int,
):
    del bt_ref  # consumed by the index maps
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos = q_pos_ref[0, 0]
    k_pos = ki * block_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_size), 1)[0]
    valid = k_pos <= q_pos
    if window > 0:
        valid = valid & (q_pos - k_pos < window)

    @pl.when(jnp.any(valid))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)        # (G, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # (bs, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                  # (G, bs)
        if softcap > 0.0:
            s = jnp.tanh(s / softcap) * softcap
        s = jnp.where(valid[None, :], s, NEG_INF)
        m_prev = m_ref[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.where(valid[None, :], jnp.exp(s - m_new[:, None]), 0.0)
        l_ref[:, 0] = l_ref[:, 0] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[:, 0] = m_new

    @pl.when(ki == num_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "softcap", "interpret")
)
def paged_decode_attention(
    q: jax.Array,              # (B, 1, Hq, D)
    k_pool: jax.Array,         # (N, bs, Hkv, D) global block pool
    v_pool: jax.Array,         # (N, bs, Hkv, D)
    block_tables: jax.Array,   # (B, nb) int32 pool indices
    q_positions: jax.Array,    # (B, 1) int32
    *,
    window: int = 0,
    softcap: float = 0.0,
    interpret: bool = False,
) -> jax.Array:
    B, S, Hq, D = q.shape
    assert S == 1, "decode kernel is single-token"
    _, bs, Hkv, _ = k_pool.shape
    G = Hq // Hkv
    nb = block_tables.shape[1]
    grid = (B, Hkv, nb)
    qg = q.reshape(B, 1, Hkv * G, D)

    kernel = functools.partial(
        _paged_kernel, window=window, softcap=softcap,
        scale=1.0 / math.sqrt(D), num_kv_blocks=nb, block_size=bs,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, ki, bt: (b, 0)),
            pl.BlockSpec((1, 1, G, D), lambda b, h, ki, bt: (b, 0, h, 0)),
            pl.BlockSpec((1, bs, 1, D), lambda b, h, ki, bt: (bt[b, ki], 0, h, 0)),
            pl.BlockSpec((1, bs, 1, D), lambda b, h, ki, bt: (bt[b, ki], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, ki, bt: (b, 0, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, 1, Hq, D), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), q_positions.astype(jnp.int32),
      qg, k_pool, v_pool)
    return out.reshape(B, 1, Hq, D)
