"""Pure-jnp oracles for the decode-attention kernels.

``decode_attention``: single new query token per sequence attends over a
(possibly ring-buffered) contiguous KV cache.  Slots with k_position == -1
are unfilled and masked; window masking uses absolute positions so ring
buffers work unchanged.

``paged_decode_attention``: same math over a paged cache — K/V are gathered
from a global block pool through a per-sequence block table, and key
positions are synthesized (gathered index j == absolute position j), so
causal masking hides both the unwritten tail of the last block and any
garbage-block table entries (their positions all exceed the query's).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30


def decode_attention(
    q: jax.Array,          # (B, 1, Hq, D)
    k_cache: jax.Array,    # (B, L, Hkv, D)
    v_cache: jax.Array,    # (B, L, Hkv, D)
    *,
    q_positions: jax.Array,   # (B, 1)
    k_positions: jax.Array,   # (B, L)
    window: int = 0,
    softcap: float = 0.0,
) -> jax.Array:
    B, S, Hq, D = q.shape
    Hkv = k_cache.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, S, Hkv, G, D)
    scores = jnp.einsum(
        "bshgd,bthd->bhgst", qg, k_cache, preferred_element_type=jnp.float32
    ) / math.sqrt(D)
    if softcap > 0.0:
        scores = jnp.tanh(scores / softcap) * softcap
    valid = (k_positions >= 0) & (k_positions <= q_positions)  # (B, L)
    if window > 0:
        valid = valid & (q_positions - k_positions < window)
    scores = jnp.where(valid[:, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhgst,bthd->bshgd", probs.astype(v_cache.dtype), v_cache)
    return o.reshape(B, S, Hq, D)


def paged_decode_attention(
    q: jax.Array,              # (B, 1, Hq, D)
    k_pool: jax.Array,         # (N, bs, Hkv, D) global block pool
    v_pool: jax.Array,         # (N, bs, Hkv, D)
    *,
    block_tables: jax.Array,   # (B, max_blocks) int32 pool indices
    q_positions: jax.Array,    # (B, 1)
    window: int = 0,
    softcap: float = 0.0,
) -> jax.Array:
    B, nb = block_tables.shape
    bs = k_pool.shape[1]
    L = nb * bs
    k = k_pool[block_tables].reshape(B, L, *k_pool.shape[2:])
    v = v_pool[block_tables].reshape(B, L, *v_pool.shape[2:])
    k_positions = jnp.broadcast_to(
        jnp.arange(L, dtype=jnp.int32)[None], (B, L))
    return decode_attention(
        q, k, v, q_positions=q_positions, k_positions=k_positions,
        window=window, softcap=softcap)
