"""Pure-jnp oracle for the decode-attention kernel.

Single new query token per sequence attends over a (possibly ring-buffered)
KV cache.  Slots with k_position == -1 are unfilled and masked; window
masking uses absolute positions so ring buffers work unchanged.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30


def decode_attention(
    q: jax.Array,          # (B, 1, Hq, D)
    k_cache: jax.Array,    # (B, L, Hkv, D)
    v_cache: jax.Array,    # (B, L, Hkv, D)
    *,
    q_positions: jax.Array,   # (B, 1)
    k_positions: jax.Array,   # (B, L)
    window: int = 0,
    softcap: float = 0.0,
) -> jax.Array:
    B, S, Hq, D = q.shape
    Hkv = k_cache.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, S, Hkv, G, D)
    scores = jnp.einsum(
        "bshgd,bthd->bhgst", qg, k_cache, preferred_element_type=jnp.float32
    ) / math.sqrt(D)
    if softcap > 0.0:
        scores = jnp.tanh(scores / softcap) * softcap
    valid = (k_positions >= 0) & (k_positions <= q_positions)  # (B, L)
    if window > 0:
        valid = valid & (q_positions - k_positions < window)
    scores = jnp.where(valid[:, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhgst,bthd->bshgd", probs.astype(v_cache.dtype), v_cache)
    return o.reshape(B, S, Hq, D)
