"""Jit wrapper for the decode-attention kernel (inference-only, no vjp)."""

from __future__ import annotations

import jax

from repro.kernels.decode_attention.decode_attention import (
    decode_attention as _pallas,
)
from repro.kernels.decode_attention.decode_attention import (
    paged_decode_attention as _pallas_paged,
)


def _pick_block(L: int) -> int:
    for b in (512, 256, 128, 64, 32, 16, 8, 4, 2, 1):
        if L % b == 0:
            return b
    return 1


def decode_attention(q, k_cache, v_cache, *, q_positions, k_positions,
                     window=0, softcap=0.0, interpret=False):
    return _pallas(
        q, k_cache, v_cache, q_positions, k_positions,
        window=window, softcap=softcap,
        block_kv=_pick_block(k_cache.shape[1]), interpret=interpret,
    )


def paged_decode_attention(q, k_pool, v_pool, *, block_tables, q_positions,
                           window=0, softcap=0.0, interpret=False):
    """Paged variant: kv tiles DMA'd straight from the pool via the
    scalar-prefetched block table (tile size == pool block size)."""
    return _pallas_paged(
        q, k_pool, v_pool, block_tables, q_positions,
        window=window, softcap=softcap, interpret=interpret,
    )
