"""Jit wrapper for the fused RMSNorm kernel (fwd Pallas, bwd reference vjp)."""

from __future__ import annotations

import functools

import jax

from repro.kernels.rmsnorm import ref
from repro.kernels.rmsnorm.rmsnorm import rmsnorm as _pallas


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _rmsnorm(x, scale, eps, interpret):
    return _pallas(x, scale, eps=eps, interpret=interpret)


def _fwd(x, scale, eps, interpret):
    return _rmsnorm(x, scale, eps, interpret), (x, scale)


def _bwd(eps, interpret, res, g):
    x, scale = res
    _, vjp = jax.vjp(lambda x_, s_: ref.rmsnorm(x_, s_, eps=eps), x, scale)
    return vjp(g)


_rmsnorm.defvjp(_fwd, _bwd)


def rmsnorm(x, scale, eps=1e-6, interpret=False):
    return _rmsnorm(x, scale, eps, interpret)
