"""Pure-jnp oracle for the fused RMSNorm kernel (gemma-style 1+scale)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)
