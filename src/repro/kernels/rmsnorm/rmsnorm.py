"""Pallas TPU fused RMSNorm (gemma-style ``x * rsqrt(ms) * (1 + scale)``).

One HBM round-trip: rows stream through VMEM in (block_rows, d) tiles, the
mean-square reduction and the scale multiply fuse into a single kernel
(XLA emits reduce + broadcast-multiply as separate fusions with an extra
intermediate when the row doesn't fit a single fusion).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)            # (br, d)
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps)
    o_ref[...] = (y * (1.0 + s_ref[...].astype(jnp.float32))).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(
    x: jax.Array,        # (..., d)
    scale: jax.Array,    # (d,)
    *,
    eps: float = 1e-6,
    block_rows: int = 256,
    interpret: bool = False,
) -> jax.Array:
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    xf = x.reshape(rows, d)
    br = min(block_rows, rows)
    while rows % br:
        br -= 1
    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(xf, scale)
    return out.reshape(orig_shape)
