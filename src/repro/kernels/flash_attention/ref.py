"""Pure-jnp oracle for the flash-attention kernel (GQA, causal/windowed)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import flags

NEG_INF = -2.0 ** 30


def attention(
    q: jax.Array,          # (B, S, Hq, D)
    k: jax.Array,          # (B, T, Hkv, D)
    v: jax.Array,          # (B, T, Hkv, D)
    *,
    q_positions: jax.Array,    # (B, S) int32
    k_positions: jax.Array,    # (B, T) int32; -1 marks unfilled slots
    causal: bool,
    window: int = 0,
    softcap: float = 0.0,
) -> jax.Array:
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, S, Hkv, G, D)
    scores = jnp.einsum(
        "bshgd,bthd->bhgst", qg, k, preferred_element_type=jnp.float32
    ) / math.sqrt(D)
    if softcap > 0.0:
        scores = jnp.tanh(scores / softcap) * softcap
    valid = (k_positions >= 0)[:, None, None, None, :]
    if causal:
        valid = valid & (
            q_positions[:, None, None, :, None]
            >= k_positions[:, None, None, None, :]
        )
    if window > 0:
        valid = valid & (
            q_positions[:, None, None, :, None]
            - k_positions[:, None, None, None, :]
            < window
        )
    scores = jnp.where(valid, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhgst,bthd->bshgd", probs.astype(v.dtype), v)
    return o.reshape(B, S, Hq, D)


def attention_chunked(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    q_positions: jax.Array,
    k_positions: jax.Array,
    causal: bool,
    window: int = 0,
    softcap: float = 0.0,
    block_q: int = 1024,
) -> jax.Array:
    """Flash-style chunked attention in pure jnp (exact, online softmax).

    Memory scales with block_q x T instead of S x T — this is what the XLA
    (non-Pallas) path lowers for 32k prefill so the dry-run never
    materializes S x S scores.  lax.scan over q blocks; the scan is
    log-compact HLO (and the dry-run's FLOPs correction accounts for it via
    the unrolled lowering).
    """
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    bq = min(block_q, S)
    if S % bq:
        pad = bq - S % bq
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_positions = jnp.pad(q_positions, ((0, 0), (0, pad)),
                              constant_values=-1)
        S_pad = S + pad
    else:
        S_pad = S
    nq = S_pad // bq
    T = k.shape[1]
    qs = q.reshape(B, nq, bq, Hq, D).swapaxes(0, 1)          # (nq, B, bq, Hq, D)
    qp = q_positions.reshape(B, nq, bq).swapaxes(0, 1)       # (nq, B, bq)
    scale = 1.0 / math.sqrt(D)
    # windowed attention over a contiguous layout only needs the KV band
    # [i*bq - window, (i+1)*bq) per q block — avoids a window/seq-fold FLOPs
    # overcount in the lowered HLO (and at runtime on the XLA path).
    band = bq + (window if window > 0 else 0)
    use_band = window > 0 and causal and band < T

    def per_block(_, xs):
        idx, qb, qpb = xs
        if use_band:
            start = jnp.clip(idx * bq - (band - bq), 0, T - band)
            kb = jax.lax.dynamic_slice_in_dim(k, start, band, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(v, start, band, axis=1)
            kpb = jax.lax.dynamic_slice_in_dim(k_positions, start, band, axis=1)
        else:
            kb, vb, kpb = k, v, k_positions
        qg = qb.reshape(B, bq, Hkv, G, D)
        s = jnp.einsum("bshgd,bthd->bhgst", qg, kb,
                       preferred_element_type=jnp.float32) * scale
        if softcap > 0.0:
            s = jnp.tanh(s / softcap) * softcap
        valid = (kpb >= 0)[:, None, None, None, :] \
            & (qpb >= 0)[:, None, None, :, None]
        if causal:
            valid = valid & (qpb[:, None, None, :, None]
                             >= kpb[:, None, None, None, :])
        if window > 0:
            valid = valid & (qpb[:, None, None, :, None]
                             - kpb[:, None, None, None, :] < window)
        s = jnp.where(valid, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgst,bthd->bshgd", p.astype(vb.dtype), vb)
        return None, o.reshape(B, bq, Hq, D)

    idxs = jnp.arange(nq, dtype=jnp.int32)
    _, outs = jax.lax.scan(per_block, None, (idxs, qs, qp),
                           unroll=nq if flags.unroll_scans() else 1)
    out = outs.swapaxes(0, 1).reshape(B, S_pad, Hq, D)
    return out[:, :S]
