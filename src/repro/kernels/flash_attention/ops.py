"""Jit wrapper for the flash-attention kernel.

Forward runs the Pallas kernel; backward differentiates the reference
implementation (numerically identical math) via ``custom_vjp`` — the
training path stays end-to-end differentiable with the kernel enabled.
A dedicated backward kernel is a tracked perf-iteration item.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import ref
from repro.kernels.flash_attention.flash_attention import (
    flash_attention as _pallas_fwd,
)


def _pick_blocks(S: int, T: int):
    bq = 128 if S % 128 == 0 else max(g for g in (64, 32, 16, 8, 4, 2, 1) if S % g == 0)
    bk = 256 if T % 256 == 0 else max(g for g in (128, 64, 32, 16, 8, 4, 2, 1) if T % g == 0)
    return min(bq, S), min(bk, T)


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8)
)
def _attn(q, k, v, q_positions, k_positions, causal, window, softcap, interpret):
    bq, bk = _pick_blocks(q.shape[1], k.shape[1])
    return _pallas_fwd(
        q, k, v, q_positions, k_positions,
        causal=causal, window=window, softcap=softcap,
        block_q=bq, block_kv=bk, interpret=interpret,
    )


def _attn_fwd(q, k, v, q_positions, k_positions, causal, window, softcap, interpret):
    out = _attn(q, k, v, q_positions, k_positions, causal, window, softcap, interpret)
    return out, (q, k, v, q_positions, k_positions)


def _attn_bwd(causal, window, softcap, interpret, res, g):
    q, k, v, q_positions, k_positions = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: ref.attention(
            q_, k_, v_, q_positions=q_positions, k_positions=k_positions,
            causal=causal, window=window, softcap=softcap,
        ),
        q, k, v,
    )
    dq, dk, dv = vjp(g)
    return dq, dk, dv, None, None


_attn.defvjp(_attn_fwd, _attn_bwd)


def flash_attention(q, k, v, *, q_positions, k_positions, causal, window=0,
                    softcap=0.0, interpret=False):
    return _attn(q, k, v, q_positions, k_positions, causal, window, softcap,
                 interpret)
