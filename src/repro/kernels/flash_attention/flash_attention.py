"""Pallas TPU flash attention (GQA, causal / sliding-window, soft-cap).

TPU-native design (not a CUDA port):
  * grid = (batch, q_heads, q_blocks, kv_blocks) — kv is the innermost
    (sequential) dimension so the online-softmax accumulators live in VMEM
    scratch across kv steps; batch/head/q dims are parallel.
  * K/V tiles stream HBM→VMEM via BlockSpecs; block sizes default to 128
    (q) × 256 (kv), multiples of the 128-lane MXU tiling.
  * positions-based masking: causality, ring-buffer validity (pos == -1)
    and sliding windows are all expressed on absolute positions, so the
    same kernel serves training, prefill, and windowed layers.
  * fully-masked kv blocks are skipped per-block using the loaded position
    tiles (a dynamic analogue of the static causal block-skip).
  * softmax statistics in fp32 regardless of input dtype.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30


def _kernel(
    q_pos_ref, k_pos_ref,          # (1, bq) / (1, bk) int32
    q_ref, k_ref, v_ref,           # (1, bq, 1, D) / (1, bk, 1, D)
    o_ref,                         # (1, bq, 1, D)
    acc_ref, m_ref, l_ref,         # VMEM scratch: (bq, D) f32, (bq, 1) f32 x2
    *,
    causal: bool,
    window: int,
    softcap: float,
    scale: float,
    num_kv_blocks: int,
):
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos = q_pos_ref[0]                      # (bq,)
    k_pos = k_pos_ref[0]                      # (bk,)

    # dynamic block-skip: can any query in this tile see any key in that tile?
    visible = jnp.asarray(True)
    if causal:
        visible = jnp.max(q_pos) >= jnp.min(jnp.where(k_pos < 0, 2**30, k_pos))
    if window > 0:
        visible = visible & (jnp.min(q_pos) - jnp.max(k_pos) < window)
    visible = visible & jnp.any(k_pos >= 0)

    @pl.when(visible)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)   # (bq, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)   # (bk, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                   # (bq, bk)
        if softcap > 0.0:
            s = jnp.tanh(s / softcap) * softcap
        valid = (k_pos >= 0)[None, :]
        if causal:
            valid = valid & (q_pos[:, None] >= k_pos[None, :])
        if window > 0:
            valid = valid & (q_pos[:, None] - k_pos[None, :] < window)
        s = jnp.where(valid, s, NEG_INF)

        m_prev = m_ref[:, 0]                        # (bq,)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(valid, p, 0.0)
        l_ref[:, 0] = l_ref[:, 0] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[:, 0] = m_new

    @pl.when(ki == num_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "block_q", "block_kv",
                     "interpret"),
)
def flash_attention(
    q: jax.Array,              # (B, S, Hq, D)
    k: jax.Array,              # (B, T, Hkv, D)
    v: jax.Array,
    q_positions: jax.Array,    # (B, S) int32
    k_positions: jax.Array,    # (B, T) int32
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    block_q: int = 128,
    block_kv: int = 256,
    interpret: bool = False,
) -> jax.Array:
    B, S, Hq, D = q.shape
    _, T, Hkv, _ = k.shape
    assert Hq % Hkv == 0
    bq = min(block_q, S)
    bk = min(block_kv, T)
    assert S % bq == 0 and T % bk == 0, (S, bq, T, bk)
    nq, nk = S // bq, T // bk
    grid = (B, Hq, nq, nk)
    kv_map = lambda b, h, qi, ki: (b, ki, h * Hkv // Hq, 0)

    kernel = functools.partial(
        _kernel, causal=causal, window=window, softcap=softcap,
        scale=1.0 / math.sqrt(D), num_kv_blocks=nk,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq), lambda b, h, qi, ki: (b, qi)),
            pl.BlockSpec((1, bk), lambda b, h, qi, ki: (b, ki)),
            pl.BlockSpec((1, bq, 1, D), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, bk, 1, D), kv_map),
            pl.BlockSpec((1, bk, 1, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, D), lambda b, h, qi, ki: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q_positions.astype(jnp.int32), k_positions.astype(jnp.int32), q, k, v)
