from repro.kernels import dispatch  # noqa: F401
from repro.kernels.dispatch import set_backend, use_backend  # noqa: F401
