"""Kernel dispatch: route hot-spot ops to Pallas TPU kernels or the pure-jnp
reference implementations.

Backend selection:
  * ``auto``   — Pallas on TPU, reference elsewhere (default).
  * ``pallas`` — force Pallas (with ``interpret=True`` off-TPU; used by tests).
  * ``xla``    — force the pure-jnp reference.  The multi-pod dry-run uses
    this so ``compiled.cost_analysis()`` sees real HLO FLOPs (a Pallas call
    is an opaque custom-call to XLA's cost model).

The reference implementations live in each kernel's ``ref.py`` and are the
oracles the Pallas kernels are tested against.
"""

from __future__ import annotations

import contextlib
import threading

import jax


class _State(threading.local):
    def __init__(self):
        self.backend = "auto"  # auto | pallas | xla
        self.interpret = False


_STATE = _State()


def set_backend(backend: str, interpret: bool = False) -> None:
    assert backend in ("auto", "pallas", "xla"), backend
    _STATE.backend = backend
    _STATE.interpret = interpret


@contextlib.contextmanager
def use_backend(backend: str, interpret: bool = False):
    prev = (_STATE.backend, _STATE.interpret)
    set_backend(backend, interpret)
    try:
        yield
    finally:
        _STATE.backend, _STATE.interpret = prev


def _use_pallas() -> bool:
    if _STATE.backend == "pallas":
        return True
    if _STATE.backend == "xla":
        return False
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return _STATE.interpret or jax.default_backend() != "tpu"


# --------------------------------------------------------------------------
# public entry points
# --------------------------------------------------------------------------

def flash_attention(q, k, v, *, q_positions, k_positions, causal, window=0,
                    softcap=0.0):
    if _use_pallas():
        from repro.kernels.flash_attention import ops
        return ops.flash_attention(
            q, k, v, q_positions=q_positions, k_positions=k_positions,
            causal=causal, window=window, softcap=softcap,
            interpret=_interpret(),
        )
    from repro.kernels.flash_attention import ref
    # avoid materializing S x T fp32 scores for long sequences on the XLA
    # path (threshold lowered 4096^2 -> 2048^2 in EXPERIMENTS §Perf llava
    # iteration 2: the naive path's S x S fp32 score tensors dominated the
    # train_4k memory roofline term ~10x)
    if q.shape[1] * k.shape[1] > 2048 * 2048:
        return ref.attention_chunked(
            q, k, v, q_positions=q_positions, k_positions=k_positions,
            causal=causal, window=window, softcap=softcap,
        )
    return ref.attention(
        q, k, v, q_positions=q_positions, k_positions=k_positions,
        causal=causal, window=window, softcap=softcap,
    )


def decode_attention(q, k_cache, v_cache, *, q_positions, k_positions,
                     window=0, softcap=0.0):
    if _use_pallas():
        from repro.kernels.decode_attention import ops
        return ops.decode_attention(
            q, k_cache, v_cache, q_positions=q_positions,
            k_positions=k_positions, window=window, softcap=softcap,
            interpret=_interpret(),
        )
    from repro.kernels.decode_attention import ref
    return ref.decode_attention(
        q, k_cache, v_cache, q_positions=q_positions, k_positions=k_positions,
        window=window, softcap=softcap,
    )


def paged_decode_attention(q, k_pool, v_pool, *, block_tables, q_positions,
                           window=0, softcap=0.0):
    """Decode attention over a paged (block-pool) KV cache."""
    if _use_pallas():
        from repro.kernels.decode_attention import ops
        return ops.paged_decode_attention(
            q, k_pool, v_pool, block_tables=block_tables,
            q_positions=q_positions, window=window, softcap=softcap,
            interpret=_interpret(),
        )
    from repro.kernels.decode_attention import ref
    return ref.paged_decode_attention(
        q, k_pool, v_pool, block_tables=block_tables,
        q_positions=q_positions, window=window, softcap=softcap,
    )


def linear_recurrence(a, b, h0):
    """h_t = a_t * h_{t-1} + b_t over axis 1.  a,b: (B,S,W) fp32; h0: (B,W)."""
    if _use_pallas():
        from repro.kernels.linear_recurrence import ops
        return ops.linear_recurrence(a, b, h0, interpret=_interpret())
    from repro.kernels.linear_recurrence import ref
    return ref.linear_recurrence(a, b, h0)


def rmsnorm(x, scale, eps=1e-6):
    if _use_pallas():
        from repro.kernels.rmsnorm import ops
        return ops.rmsnorm(x, scale, eps=eps, interpret=_interpret())
    from repro.kernels.rmsnorm import ref
    return ref.rmsnorm(x, scale, eps=eps)
