"""Fault-tolerant checkpointing: atomic step-directories, content manifest,
resume-from-latest, and elastic restore onto a different mesh.

Layout (one directory per step, atomically renamed into place):

    ckpt_dir/
      step_000100/
        manifest.json       # step, config name, tree structure, shapes,
                            # dtypes, data position, wall time, host count
        arrays.npz          # flattened path -> array
      step_000200/ ...
      LATEST                # text file: last durable step dir name

Writes go to ``step_XXXX.tmp`` then ``os.replace`` — a crash mid-write never
corrupts a durable checkpoint.  ``restore`` accepts a target mesh + sharding
tree: arrays are re-``device_put`` under the new sharding, which is what
makes restarting on a *different* pod slice (elastic re-mesh) a plain
restore call.  On multi-host deployments each host writes
``arrays.<process_index>.npz`` with its addressable shards.
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out


def _unflatten_into(template, arrays: Dict[str, np.ndarray]):
    flat = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in flat[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing array {key!r}")
        leaves.append(arrays[key])
    return jax.tree_util.tree_unflatten(flat[1], leaves)


def save(
    ckpt_dir: str,
    step: int,
    tree,
    *,
    metadata: Optional[Dict] = None,
    keep: int = 3,
) -> str:
    """Atomically persist ``tree`` for ``step``; returns the final dir."""
    os.makedirs(ckpt_dir, exist_ok=True)
    name = f"step_{step:08d}"
    tmp = os.path.join(ckpt_dir, name + ".tmp")
    final = os.path.join(ckpt_dir, name)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    host = {k: np.asarray(v) for k, v in flat.items()}
    suffix = "" if jax.process_count() == 1 else f".{jax.process_index()}"
    np.savez(os.path.join(tmp, f"arrays{suffix}.npz"), **host)
    manifest = {
        "step": step,
        "time": time.time(),
        "process_count": jax.process_count(),
        "arrays": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in host.items()},
    }
    manifest.update(metadata or {})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    with open(os.path.join(ckpt_dir, "LATEST.tmp"), "w") as f:
        f.write(name)
    os.replace(os.path.join(ckpt_dir, "LATEST.tmp"),
               os.path.join(ckpt_dir, "LATEST"))
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(
        d for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    path = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        name = f.read().strip()
    if not os.path.isdir(os.path.join(ckpt_dir, name)):
        return None
    return int(name.split("_")[1])


def restore(
    ckpt_dir: str,
    template,
    *,
    step: Optional[int] = None,
    shardings=None,
) -> Tuple[Any, Dict]:
    """Load a checkpoint into ``template``'s structure.

    ``shardings``: optional pytree (or flat dict path->NamedSharding) — each
    array is ``device_put`` under it, which reshards onto whatever mesh the
    caller is running now (elastic restart).
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    arrays: Dict[str, np.ndarray] = {}
    for fn in sorted(os.listdir(d)):
        if fn.startswith("arrays") and fn.endswith(".npz"):
            with np.load(os.path.join(d, fn)) as z:
                arrays.update({k: z[k] for k in z.files})
    tree = _unflatten_into(template, arrays)
    if shardings is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(x, s) if s is not None else jax.device_put(x),
            tree, shardings,
        )
    else:
        tree = jax.tree.map(jax.device_put, tree)
    return tree, manifest
