"""Fault-tolerance runtime pieces: preemption handling, straggler watchdog,
and elastic-restart bookkeeping.

At 1000+ node scale the failure model is: (a) planned preemption (SIGTERM
with a grace window), (b) node loss mid-step (detected as a step timeout /
collective error -> whole-job restart from the last durable checkpoint),
(c) persistent stragglers (hardware throttling) that stretch step time.
The pieces here cover the in-process halves of those: catch the signal and
checkpoint before dying; track per-step timing statistics and flag outliers;
record the data-stream position so restarts are sample-exact.
"""

from __future__ import annotations

import dataclasses
import signal
import threading
import time
from typing import Callable, Dict, List, Optional


class PreemptionHandler:
    """SIGTERM/SIGINT -> set a flag the train loop polls at step boundaries."""

    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self._requested = threading.Event()
        self._prev = {}
        self._signals = signals

    def install(self) -> "PreemptionHandler":
        for sig in self._signals:
            self._prev[sig] = signal.signal(sig, self._on_signal)
        return self

    def uninstall(self) -> None:
        for sig, prev in self._prev.items():
            signal.signal(sig, prev)
        self._prev.clear()

    def _on_signal(self, signum, frame) -> None:
        self._requested.set()

    @property
    def preemption_requested(self) -> bool:
        return self._requested.is_set()

    # test hook / cooperative preemption
    def request(self) -> None:
        self._requested.set()


@dataclasses.dataclass
class StepTiming:
    step: int
    seconds: float
    is_straggler: bool
    ewma: float


class StragglerWatchdog:
    """EWMA step-time tracker; flags steps slower than ``threshold``x EWMA.

    On a real pod this feeds the controller that decides to evict/replace a
    slow host; here it logs and counts (and its history is assertable in
    tests).  ``hard_timeout_s`` is the give-up bound for hung collectives.
    """

    def __init__(self, alpha: float = 0.1, threshold: float = 2.0,
                 hard_timeout_s: float = 3600.0,
                 on_straggler: Optional[Callable[[StepTiming], None]] = None):
        self.alpha = alpha
        self.threshold = threshold
        self.hard_timeout_s = hard_timeout_s
        self.on_straggler = on_straggler
        self.history: List[StepTiming] = []
        self._ewma: Optional[float] = None
        self._t0: Optional[float] = None

    def start_step(self) -> None:
        self._t0 = time.perf_counter()

    def end_step(self, step: int) -> StepTiming:
        assert self._t0 is not None, "start_step not called"
        dt = time.perf_counter() - self._t0
        self._t0 = None
        if self._ewma is None:
            self._ewma = dt
        is_straggler = dt > self.threshold * self._ewma
        if not is_straggler:  # don't poison the EWMA with outliers
            self._ewma = (1 - self.alpha) * self._ewma + self.alpha * dt
        timing = StepTiming(step=step, seconds=dt, is_straggler=is_straggler,
                            ewma=self._ewma)
        self.history.append(timing)
        if is_straggler and self.on_straggler:
            self.on_straggler(timing)
        return timing

    @property
    def straggler_count(self) -> int:
        return sum(1 for t in self.history if t.is_straggler)

    @property
    def mean_step_s(self) -> float:
        if not self.history:
            return 0.0
        return sum(t.seconds for t in self.history) / len(self.history)


@dataclasses.dataclass
class RunPosition:
    """Everything needed to resume sample-exact after a restart."""

    step: int
    data_epoch: int
    data_offset: int            # samples consumed within the epoch
    rng_seed: int

    def to_metadata(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_metadata(cls, meta: Dict) -> "RunPosition":
        return cls(step=int(meta.get("step", 0)),
                   data_epoch=int(meta.get("data_epoch", 0)),
                   data_offset=int(meta.get("data_offset", 0)),
                   rng_seed=int(meta.get("rng_seed", 0)))
