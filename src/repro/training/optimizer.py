"""AdamW + schedules, pure JAX (no optax dependency).

Optimizer state mirrors the parameter pytree (same logical sharding axes →
ZeRO-style sharding for free: whatever FSDP axes the params use, the moments
use too).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    mu: Dict
    nu: Dict
    count: jax.Array


Schedule = Callable[[jax.Array], jax.Array]


def constant_schedule(lr: float) -> Schedule:
    return lambda step: jnp.asarray(lr, jnp.float32)


def warmup_cosine_schedule(
    peak_lr: float, warmup_steps: int, total_steps: int, min_ratio: float = 0.1
) -> Schedule:
    def fn(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * jnp.minimum(1.0, step / max(warmup_steps, 1))
        frac = jnp.clip(
            (step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0
        )
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup_steps, warm, peak_lr * cos)

    return fn


@dataclasses.dataclass(frozen=True)
class AdamW:
    schedule: Schedule
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    # decay only matrices (embeddings/projections), not norms/biases
    decay_min_ndim: int = 2

    def init(self, params) -> OptState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return OptState(
            mu=jax.tree.map(zeros, params),
            nu=jax.tree.map(zeros, params),
            count=jnp.zeros((), jnp.int32),
        )

    def update(self, grads, state: OptState, params) -> Tuple[Dict, OptState]:
        count = state.count + 1
        lr = self.schedule(count)
        b1c = 1.0 - self.b1 ** count.astype(jnp.float32)
        b2c = 1.0 - self.b2 ** count.astype(jnp.float32)

        def upd(g, mu, nu, p):
            g = g.astype(jnp.float32)
            mu = self.b1 * mu + (1 - self.b1) * g
            nu = self.b2 * nu + (1 - self.b2) * jnp.square(g)
            step = (mu / b1c) / (jnp.sqrt(nu / b2c) + self.eps)
            if p.ndim >= self.decay_min_ndim:
                step = step + self.weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
            return new_p, mu, nu

        out = jax.tree.map(upd, grads, state.mu, state.nu, params)
        new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
        new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
        return new_params, OptState(mu=new_mu, nu=new_nu, count=count)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree)
    ))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree), norm
