"""Train-step builder: loss, gradient accumulation, clipping, optimizer.

The returned ``train_step(state, batch)`` is a pure function ready for
``jax.jit`` with in/out shardings from ``sharding.partition``.  Microbatched
gradient accumulation runs under ``lax.scan`` so the HLO stays compact and
the MoE dispatch buffers scale with the microbatch, not the global batch.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import flags
from repro.models import model as model_lib
from repro.models.config import ModelConfig
from repro.training.optimizer import AdamW, OptState, clip_by_global_norm


class TrainState(NamedTuple):
    params: Dict
    opt: OptState
    step: jax.Array


def init_state(cfg: ModelConfig, optimizer: AdamW, key) -> Tuple[TrainState, Dict]:
    params, axes = model_lib.init(cfg, key)
    return TrainState(params=params, opt=optimizer.init(params),
                      step=jnp.zeros((), jnp.int32)), axes


def cross_entropy(
    logits: jax.Array,      # (B, S, V) fp32
    labels: jax.Array,      # (B, S) int32
    mask: Optional[jax.Array] = None,  # (B, S) 1.0 = count
    z_loss: float = 1e-4,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    true_logit = jnp.take_along_axis(
        logits, labels[..., None].astype(jnp.int32), axis=-1
    )[..., 0]
    nll = lse - true_logit
    if z_loss > 0:  # PaLM-style logit-norm regularizer (keeps lse bounded)
        nll = nll + z_loss * jnp.square(lse)
    if mask is None:
        mask = jnp.ones(labels.shape, jnp.float32)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(nll * mask) / denom
    acc = jnp.sum((jnp.argmax(logits, -1) == labels) * mask) / denom
    return loss, {"loss": loss, "accuracy": acc, "tokens": denom}


def make_loss_fn(cfg: ModelConfig, remat: bool = True):
    def loss_fn(params, batch) -> Tuple[jax.Array, Dict]:
        logits = model_lib.forward_train(cfg, params, batch, remat=remat)
        labels = batch["labels"]
        mask = batch.get("loss_mask")
        if cfg.num_vision_tokens and logits.shape[1] != labels.shape[1]:
            logits = logits[:, cfg.num_vision_tokens:]  # text positions only
        return cross_entropy(logits, labels, mask)

    return loss_fn


def make_train_step(
    cfg: ModelConfig,
    optimizer: AdamW,
    *,
    remat: bool = True,
    microbatches: int = 1,
    clip_norm: float = 1.0,
    param_pspecs=None,
) -> Callable[[TrainState, Dict], Tuple[TrainState, Dict]]:
    """``param_pspecs`` (optional PartitionSpec tree matching params) pins the
    gradient accumulator to the parameter sharding — XLA then reduce-scatters
    per-microbatch partial gradients instead of all-reducing replicated fp32
    buffers (EXPERIMENTS §Perf iteration 1)."""
    loss_fn = make_loss_fn(cfg, remat=remat)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def constrain(tree):
        if param_pspecs is None:
            return tree
        return jax.tree.map(
            lambda g, ps: jax.lax.with_sharding_constraint(g, ps), tree,
            param_pspecs)

    def train_step(state: TrainState, batch: Dict) -> Tuple[TrainState, Dict]:
        if microbatches > 1:
            def split(x):
                b = x.shape[0]
                assert b % microbatches == 0, (b, microbatches)
                return x.reshape(microbatches, b // microbatches, *x.shape[1:])

            micro = jax.tree.map(split, batch)

            def accum(carry, mb):
                (loss_sum, grads_sum) = carry
                (loss, aux), grads = grad_fn(state.params, mb)
                grads = constrain(grads)
                grads_sum = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), grads_sum, grads)
                return (loss_sum + loss, constrain(grads_sum)), aux

            zero_grads = constrain(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params))
            (loss_sum, grads), aux = jax.lax.scan(
                accum, (jnp.zeros((), jnp.float32), zero_grads), micro,
                unroll=microbatches if flags.unroll_scans() else 1)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            aux = jax.tree.map(lambda x: x[-1], aux)
            aux["loss"] = loss_sum / microbatches
        else:
            (_, aux), grads = grad_fn(state.params, batch)
            grads = constrain(grads)

        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        new_params, new_opt = optimizer.update(grads, state.opt, state.params)
        metrics = dict(aux)
        metrics["grad_norm"] = gnorm
        metrics["lr"] = optimizer.schedule(new_opt.count)
        return TrainState(params=new_params, opt=new_opt, step=state.step + 1), metrics

    return train_step
