"""OpenAI-compatible streaming HTTP front-end for the serving engine.

The engine is single-threaded by construction (one fused dispatch per
step, host-side scheduler state), so the server keeps it that way: a
dedicated *engine thread* owns the ``ServingEngine`` exclusively and runs
the admit/step loop, while an asyncio ``aiohttp`` application accepts
requests on its own event loop.  The two sides meet at exactly two
points:

* a thread-safe **submission queue** — each ``POST /v1/completions``
  enqueues ``(prompt, params, stream-handle)``; the engine thread drains
  it before every step and maps the engine-assigned uid back to the
  handle;
* the engine's **stream hook** — tokens are pushed to the request's
  asyncio queue from inside the per-step host sync (the moment they
  leave the device, before the ring buffer defers them), so SSE chunks
  carry per-step latency, and the finish edge carries the request's
  engine-side timestamps and attributed joules.

Endpoints (OpenAI completions shape, minus a tokenizer — prompts are
token-id lists, or strings byte-encoded into the vocab):

* ``POST /v1/completions`` — ``stream=true`` for SSE chunks terminated
  by ``data: [DONE]``; ``stream=false`` for one JSON body.  Each chunk's
  ``elana`` extension carries the raw token ids and emit timestamp; the
  final chunk's carries engine-side submit/first-token/finish stamps so
  a same-host client can compute client-vs-engine latency deltas
  (``time.perf_counter`` is CLOCK_MONOTONIC: one clock per machine).
* ``GET /v1/models`` — the single served model.
* ``GET /metrics`` — ``engine.latency_summary()`` plus server counters,
  as JSON.

``start_http_server`` wires it all up on an ephemeral port and returns a
handle; ``launch/serve.py --http-port`` and ``launch/bench_serve.py``
are the CLI entry points.
"""

from __future__ import annotations

import asyncio
import dataclasses
import json
import queue
import threading
import time
from typing import Dict, List, Optional

import numpy as np

from repro.serving.engine import Request, ServingEngine
from repro.serving.sampling import SamplingParams

try:  # aiohttp is a dev/serving extra, not a core runtime dependency
    from aiohttp import web
except ImportError:  # pragma: no cover - exercised only without aiohttp
    web = None


def encode_prompt(prompt, vocab_size: int) -> np.ndarray:
    """Token-id list passed through (validated), or a string byte-encoded
    into the vocab (this repo has no tokenizer — the id stream *is* the
    text)."""
    if isinstance(prompt, str):
        ids = [ord(c) % vocab_size for c in prompt]
    else:
        ids = [int(t) for t in prompt]
        bad = [t for t in ids if not 0 <= t < vocab_size]
        if bad:
            raise ValueError(
                f"prompt token(s) out of range [0, {vocab_size}): {bad[:5]}")
    if not ids:
        raise ValueError("prompt must contain at least one token")
    return np.asarray(ids, np.int32)


class _Stream:
    """Engine-thread -> event-loop bridge for one request's chunks."""

    def __init__(self, loop: asyncio.AbstractEventLoop):
        self._loop = loop
        self.q: asyncio.Queue = asyncio.Queue()
        self.uid: Optional[int] = None

    def push(self, item) -> None:
        try:
            self._loop.call_soon_threadsafe(self.q.put_nowait, item)
        except RuntimeError:  # event loop shut down mid-request
            pass


@dataclasses.dataclass
class _Submission:
    prompt: np.ndarray
    params: SamplingParams
    stream: _Stream


class EngineServer:
    """The aiohttp application + the engine thread that feeds it."""

    def __init__(self, engine: ServingEngine, *, model_name: str = "elana",
                 idle_wait_s: float = 0.01):
        if web is None:  # pragma: no cover
            raise RuntimeError(
                "aiohttp is required for the HTTP server "
                "(pip install aiohttp)")
        self.engine = engine
        self.model_name = model_name
        self.idle_wait_s = idle_wait_s
        self._subq: "queue.Queue[_Submission]" = queue.Queue()
        self._streams: Dict[int, _Stream] = {}
        self._reqs: Dict[int, Request] = {}
        # engine exclusivity: the engine thread holds it across step();
        # metrics scrapes hold it across latency_summary()
        self._lock = threading.Lock()
        self._run = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._t_started = time.perf_counter()
        self.requests_received = 0
        self.chunks_streamed = 0
        engine.stream_hook = self._on_tokens

    # -- engine thread ---------------------------------------------------------
    def _on_tokens(self, uid: int, tokens: List[int], finished: bool) -> None:
        """``engine.stream_hook`` — runs on the engine thread mid-step."""
        h = self._streams.get(uid)
        if h is None:
            return
        now = time.perf_counter()
        if tokens:
            h.push(("tokens", list(tokens), now))
        if finished:
            req = self._reqs.pop(uid, None)
            self._streams.pop(uid, None)
            h.push(("end", self._final_payload(req), now))

    @staticmethod
    def _final_payload(req: Optional[Request]) -> Dict:
        if req is None:  # pragma: no cover - submit/finish race guard
            return {}
        return {
            "engine_submit_s": req.submit_time,
            "engine_first_token_s": req.first_token_time,
            "engine_finish_s": req.finish_time,
            "engine_ttft_s": req.ttft_s,
            "engine_tpot_s": req.tpot_s,
            "prompt_tokens": len(req.prompt),
            "completion_tokens": len(req.output_tokens),
            "joules": req.joules,
            "truncated": req.truncated,
            "preemptions": req.preemptions,
        }

    def _admit(self, sub: _Submission) -> None:
        with self._lock:
            uid = self.engine.submit(sub.prompt, sub.params)
            req = self.engine.queue[-1]
        sub.stream.uid = uid
        self._reqs[uid] = req
        self._streams[uid] = sub.stream
        sub.stream.push(("begin", uid, req.submit_time))

    def _engine_loop(self) -> None:
        eng = self.engine
        while self._run.is_set():
            while True:  # drain every pending submission before the step
                try:
                    self._admit(self._subq.get_nowait())
                except queue.Empty:
                    break
            if eng.busy:
                with self._lock:
                    eng.step()
            else:
                try:  # idle: block on the queue instead of spinning
                    sub = self._subq.get(timeout=self.idle_wait_s)
                except queue.Empty:
                    continue
                self._admit(sub)
        with self._lock:
            eng.flush()

    def start_engine(self) -> None:
        self._run.set()
        self._thread = threading.Thread(
            target=self._engine_loop, daemon=True, name="elana-engine")
        self._thread.start()

    def stop_engine(self) -> None:
        self._run.clear()
        if self._thread is not None:
            self._thread.join(timeout=10.0)

    def summary(self) -> Dict:
        """Engine ``latency_summary()`` + server-side counters."""
        with self._lock:
            out = dict(self.engine.latency_summary())
        out.update({
            "server_requests_received": self.requests_received,
            "server_chunks_streamed": self.chunks_streamed,
            "server_in_flight": len(self._streams),
            "server_uptime_s": time.perf_counter() - self._t_started,
        })
        return out

    # -- handlers --------------------------------------------------------------
    def build_app(self) -> "web.Application":
        app = web.Application()
        app.router.add_post("/v1/completions", self.handle_completions)
        app.router.add_get("/v1/models", self.handle_models)
        app.router.add_get("/metrics", self.handle_metrics)
        return app

    async def handle_models(self, request: "web.Request") -> "web.Response":
        return web.json_response({
            "object": "list",
            "data": [{"id": self.model_name, "object": "model",
                      "owned_by": "elana"}],
        })

    async def handle_metrics(self, request: "web.Request") -> "web.Response":
        return web.json_response(
            self.summary(),
            dumps=lambda o: json.dumps(o, default=float))

    async def handle_completions(self, request: "web.Request"):
        try:
            body = await request.json()
        except Exception:
            return web.json_response(
                {"error": {"message": "body must be JSON"}}, status=400)
        try:
            prompt = encode_prompt(body.get("prompt", ""),
                                   self.engine.cfg.vocab_size)
            params = SamplingParams(
                temperature=float(body.get("temperature", 0.0)),
                top_k=int(body.get("top_k", 0)),
                eos_token=int(body.get("eos_token", -1)),
                max_new_tokens=int(body.get("max_tokens", 16)))
            if params.max_new_tokens < 1:
                raise ValueError("max_tokens must be >= 1")
        except (TypeError, ValueError) as e:
            return web.json_response(
                {"error": {"message": str(e)}}, status=400)

        self.requests_received += 1
        handle = _Stream(asyncio.get_running_loop())
        self._subq.put(_Submission(prompt, params, handle))
        _, uid, _submit_time = await handle.q.get()  # ("begin", uid, t)
        cid = f"cmpl-{uid}"
        created = int(time.time())

        if bool(body.get("stream", False)):
            return await self._stream_response(request, handle, cid, created,
                                               params.max_new_tokens)
        tokens: List[int] = []
        while True:
            item = await handle.q.get()
            if item[0] == "tokens":
                tokens.extend(item[1])
            else:
                payload = item[1]
                break
        return web.json_response({
            "id": cid, "object": "text_completion", "created": created,
            "model": self.model_name,
            "choices": [{
                "index": 0,
                "text": "".join(f" {t}" for t in tokens),
                "finish_reason": self._finish_reason(
                    payload, params.max_new_tokens),
            }],
            "usage": {
                "prompt_tokens": payload.get("prompt_tokens", 0),
                "completion_tokens": payload.get("completion_tokens", 0),
                "total_tokens": (payload.get("prompt_tokens", 0)
                                 + payload.get("completion_tokens", 0)),
            },
            "elana": {**payload, "tokens": tokens},
        })

    @staticmethod
    def _finish_reason(payload: Dict, max_tokens: int) -> str:
        return ("length" if payload.get("completion_tokens", 0) >= max_tokens
                else "stop")

    async def _stream_response(self, request, handle: _Stream, cid: str,
                               created: int, max_tokens: int):
        resp = web.StreamResponse(headers={
            "Content-Type": "text/event-stream",
            "Cache-Control": "no-cache",
            "Connection": "keep-alive",
        })
        await resp.prepare(request)
        index = 0
        alive = True  # keep draining after a client disconnect: the
        # engine runs the request to completion either way, and the end
        # event is what unregisters this stream's bookkeeping

        async def write(data: bytes) -> None:
            nonlocal alive
            if not alive:
                return
            try:
                await resp.write(data)
            except (ConnectionResetError, ConnectionError):
                alive = False

        while True:
            item = await handle.q.get()
            if item[0] == "tokens":
                _, toks, t_emit = item
                chunk = {
                    "id": cid, "object": "text_completion",
                    "created": created, "model": self.model_name,
                    "choices": [{"index": 0,
                                 "text": "".join(f" {t}" for t in toks),
                                 "finish_reason": None}],
                    "elana": {"tokens": toks, "first_index": index,
                              "emit_s": t_emit},
                }
                index += len(toks)
                await write(b"data: " + json.dumps(chunk).encode() + b"\n\n")
                self.chunks_streamed += 1
            else:  # ("end", payload, t)
                _, payload, _ = item
                final = {
                    "id": cid, "object": "text_completion",
                    "created": created, "model": self.model_name,
                    "choices": [{"index": 0, "text": "",
                                 "finish_reason": self._finish_reason(
                                     payload, max_tokens)}],
                    "usage": {
                        "prompt_tokens": payload.get("prompt_tokens", 0),
                        "completion_tokens": payload.get(
                            "completion_tokens", 0),
                        "total_tokens": (
                            payload.get("prompt_tokens", 0)
                            + payload.get("completion_tokens", 0)),
                    },
                    "elana": payload,
                }
                await write(b"data: " + json.dumps(final).encode() + b"\n\n")
                await write(b"data: [DONE]\n\n")
                break
        if alive:
            await resp.write_eof()
        return resp


@dataclasses.dataclass
class ServerHandle:
    """A running server: engine thread + aiohttp site on its own loop."""
    url: str
    server: EngineServer
    _loop: asyncio.AbstractEventLoop
    _runner: "web.AppRunner"
    _thread: threading.Thread

    def close(self) -> None:
        """Graceful shutdown: stop the engine loop (flushes buffers), tear
        down the HTTP site, stop and join the event-loop thread."""
        self.server.stop_engine()
        fut = asyncio.run_coroutine_threadsafe(
            self._runner.cleanup(), self._loop)
        try:
            fut.result(timeout=10.0)
        except Exception:  # pragma: no cover - best-effort teardown
            pass
        self._loop.call_soon_threadsafe(self._loop.stop)
        self._thread.join(timeout=10.0)

    def __enter__(self) -> "ServerHandle":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def start_http_server(engine: ServingEngine, *, host: str = "127.0.0.1",
                      port: int = 0, model_name: str = "elana"
                      ) -> ServerHandle:
    """Serve ``engine`` over HTTP; ``port=0`` picks an ephemeral port.

    Spins up one event-loop thread for aiohttp and one engine thread for
    the admit/step loop, and returns once both are accepting work."""
    srv = EngineServer(engine, model_name=model_name)
    loop = asyncio.new_event_loop()
    started = threading.Event()
    box: Dict[str, object] = {}

    def run() -> None:
        asyncio.set_event_loop(loop)

        async def setup():
            runner = web.AppRunner(srv.build_app())
            await runner.setup()
            site = web.TCPSite(runner, host, port)
            await site.start()
            box["runner"] = runner
            box["port"] = runner.addresses[0][1]

        loop.run_until_complete(setup())
        started.set()
        loop.run_forever()

    thread = threading.Thread(target=run, daemon=True, name="elana-http")
    thread.start()
    if not started.wait(timeout=10.0):  # pragma: no cover
        raise RuntimeError("HTTP server failed to start within 10s")
    srv.start_engine()
    return ServerHandle(url=f"http://{host}:{box['port']}", server=srv,
                        _loop=loop, _runner=box["runner"], _thread=thread)
