"""Token sampling strategies for the serving engine.

Two layers:

* ``sample``       — host-driven sampling for a single ``SamplingParams``
  (used at prefill/admission time, and by the per-slot reference path).
* ``sample_slots`` — fully batched, jit-friendly sampling where every slot
  carries its *own* temperature / top-k as device arrays.  This is the
  sampler fused into the device-resident decode step
  (``serving.step.make_decode_sample_step``): greedy and stochastic slots
  coexist in one batch without any host round-trip.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.0     # 0 -> greedy
    top_k: int = 0               # 0 -> no top-k filter
    eos_token: int = -1          # -1 -> never stops early
    max_new_tokens: int = 64


def sample(logits: jax.Array, params: SamplingParams, key: jax.Array) -> jax.Array:
    """logits (B, V) -> tokens (B,) int32."""
    if params.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / params.temperature
    if params.top_k > 0:
        vals, _ = jax.lax.top_k(logits, params.top_k)
        cutoff = vals[..., -1:]
        logits = jnp.where(logits < cutoff, -jnp.inf, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


def sample_slots(
    logits: jax.Array,
    temperature: jax.Array,
    top_k: jax.Array,
    key: jax.Array,
    *,
    k_max: int = 64,
) -> jax.Array:
    """Batched sampling with per-slot params, all on device.

    logits (B, V) float; temperature (B,) float32 (<= 0 -> greedy);
    top_k (B,) int32 (0 -> no filter) -> tokens (B,) int32.

    Greedy slots take ``argmax``; stochastic slots take a categorical draw
    from temperature-scaled logits restricted to their own top-k set (the
    cutoff is the k-th largest scaled logit, ties kept — identical
    semantics to ``sample``).  ``k_max`` is the static bound on per-slot
    top-k (a full per-slot sort would dominate the fused step at small
    batch); slot values above it are clamped to ``k_max``.
    """
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    # per-slot top-k cutoff from one static-k selection; k == 0 -> keep all
    masked = _mask_slot_logits(logits, temperature, top_k, k_max)
    sampled = jax.random.categorical(key, masked, axis=-1).astype(jnp.int32)
    return jnp.where(temperature <= 0.0, greedy, sampled)


def _mask_slot_logits(logits, temperature, top_k, k_max):
    """Shared temperature/top-k masking for the per-slot samplers."""
    V = logits.shape[-1]
    k_max = min(k_max, V)
    temp = jnp.maximum(temperature, 1e-6)[:, None]
    scaled = logits.astype(jnp.float32) / temp
    top_vals = jax.lax.top_k(scaled, k_max)[0]
    idx = jnp.clip(top_k - 1, 0, k_max - 1)[:, None]
    cutoff = jnp.take_along_axis(top_vals, idx, axis=-1)
    cutoff = jnp.where((top_k > 0)[:, None], cutoff, -jnp.inf)
    return jnp.where(scaled < cutoff, -jnp.inf, scaled)


def sample_slots_keyed(
    logits: jax.Array,
    temperature: jax.Array,
    top_k: jax.Array,
    keys: jax.Array,
    *,
    k_max: int = 64,
) -> jax.Array:
    """``sample_slots`` with an independent PRNG key per slot.

    keys (B, 2) uint32 — one legacy-format key per slot.  Each slot's draw
    is a function of *its own* key and logits row only, which is what makes
    sampled token streams invariant to scheduling: a request sampled at
    slot 3 on step 40 of a chunked engine draws the same token as at slot 0
    on step 7 of an unchunked one, provided its per-request key chain has
    advanced the same number of times (once per emitted token).
    """
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    masked = _mask_slot_logits(logits, temperature, top_k, k_max)
    sampled = jax.vmap(
        lambda k, row: jax.random.categorical(k, row)
    )(keys, masked).astype(jnp.int32)
    return jnp.where(temperature <= 0.0, greedy, sampled)


def verify_slots_keyed(
    logits: jax.Array,       # (B, K+1, V) per-position target logits
    draft: jax.Array,        # (B, K) int32 drafted continuation tokens
    draft_len: jax.Array,    # (B,) int32 valid draft tokens per slot
    temperature: jax.Array,  # (B,) float32 (<= 0 -> greedy)
    top_k: jax.Array,        # (B,) int32 (0 -> no filter)
    keys: jax.Array,         # (B, 2) uint32 per-slot PRNG chains
    *,
    active: jax.Array,       # (B,) bool — slot is verifying this step
    tokens0: jax.Array,      # (B,) int32 frozen fallback token (last emitted)
    positions: jax.Array,    # (B,) int32 position of the last emitted token
    remaining: jax.Array,    # (B,) int32 new-token budget left
    eos: jax.Array,          # (B,) int32 per-slot EOS id (-1 = never)
    max_len: int,
    k_max: int = 64,
) -> dict:
    """Scheduling-invariant speculative acceptance: the unrolled emission
    chain over a verified draft window.

    ``logits[:, i]`` is the target model's next-token distribution after
    consuming window input ``i`` (input 0 is the slot's last emitted token,
    inputs 1..K its drafted continuation).  Position 0 always emits: its
    sample is drawn exactly as the plain decode step would (one key split,
    ``sample_slots_keyed`` on the split), so the first emitted token per
    verify matches the non-speculative stream by construction.  The chain
    then *continues* to position ``i`` only while every earlier sample
    equalled the draft token fed as the next input — the verified logits
    row is the true target distribution precisely when the input prefix
    matches the emitted stream.  The emitted token is always the target
    sample (never the draft), so both greedy and sampled streams are
    byte-identical to non-speculative decoding: acceptance only decides
    how *many* chain-correct samples one dispatch may emit (emitted =
    accepted draft tokens + 1 bonus).  Each emitted token advances the
    slot's position/budget and splits its PRNG chain once — the same
    per-emitted-token discipline as ``_decode_sample_body`` — and EOS /
    budget / length exhaustion cuts the chain mid-window exactly where a
    step-at-a-time decode would have stopped.
    """
    B, K1, _ = logits.shape
    cont = active
    tok = tokens0
    done = jnp.zeros_like(active)
    done_any = jnp.zeros_like(active)
    tok_cols, emit_cols = [], []
    for i in range(K1):
        if i > 0:
            cont = cont & ~done & (i <= draft_len) & (tok == draft[:, i - 1])
        split = jax.vmap(jax.random.split)(keys)     # (B, 2, 2)
        drawn = sample_slots_keyed(logits[:, i], temperature, top_k,
                                   split[:, 0], k_max=k_max)
        tok = jnp.where(cont, drawn, tok)
        ci = cont.astype(jnp.int32)
        positions = positions + ci
        remaining = remaining - ci
        hit_eos = (eos >= 0) & (tok == eos)
        done = cont & (hit_eos | (remaining <= 0) | (positions >= max_len - 1))
        keys = jnp.where(cont[:, None], split[:, 1], keys)
        done_any = done_any | done
        tok_cols.append(tok)
        emit_cols.append(cont)
    return {
        "tokens": jnp.stack(tok_cols, axis=1),     # (B, K+1) emitted tokens
        "emit": jnp.stack(emit_cols, axis=1),      # (B, K+1) emission mask
        "done": done_any,                          # (B,) finished mid-window
        "last_token": tok,                         # (B,) next verify input
        "positions": positions,
        "remaining": remaining,
        "keys": keys,
        "active": active & ~done_any,
    }


def params_as_arrays(params: SamplingParams):
    """(temperature, top_k, eos, max_new) numpy scalars for one slot."""
    return (
        np.float32(params.temperature),
        np.int32(params.top_k),
        np.int32(params.eos_token),
        np.int32(params.max_new_tokens),
    )
