"""Token sampling strategies for the serving engine.

Two layers:

* ``sample``       — host-driven sampling for a single ``SamplingParams``
  (used at prefill/admission time, and by the per-slot reference path).
* ``sample_slots`` — fully batched, jit-friendly sampling where every slot
  carries its *own* temperature / top-k as device arrays.  This is the
  sampler fused into the device-resident decode step
  (``serving.step.make_decode_sample_step``): greedy and stochastic slots
  coexist in one batch without any host round-trip.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.0     # 0 -> greedy
    top_k: int = 0               # 0 -> no top-k filter
    eos_token: int = -1          # -1 -> never stops early
    max_new_tokens: int = 64


def sample(logits: jax.Array, params: SamplingParams, key: jax.Array) -> jax.Array:
    """logits (B, V) -> tokens (B,) int32."""
    if params.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / params.temperature
    if params.top_k > 0:
        vals, _ = jax.lax.top_k(logits, params.top_k)
        cutoff = vals[..., -1:]
        logits = jnp.where(logits < cutoff, -jnp.inf, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


def sample_slots(
    logits: jax.Array,
    temperature: jax.Array,
    top_k: jax.Array,
    key: jax.Array,
    *,
    k_max: int = 64,
) -> jax.Array:
    """Batched sampling with per-slot params, all on device.

    logits (B, V) float; temperature (B,) float32 (<= 0 -> greedy);
    top_k (B,) int32 (0 -> no filter) -> tokens (B,) int32.

    Greedy slots take ``argmax``; stochastic slots take a categorical draw
    from temperature-scaled logits restricted to their own top-k set (the
    cutoff is the k-th largest scaled logit, ties kept — identical
    semantics to ``sample``).  ``k_max`` is the static bound on per-slot
    top-k (a full per-slot sort would dominate the fused step at small
    batch); slot values above it are clamped to ``k_max``.
    """
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    # per-slot top-k cutoff from one static-k selection; k == 0 -> keep all
    masked = _mask_slot_logits(logits, temperature, top_k, k_max)
    sampled = jax.random.categorical(key, masked, axis=-1).astype(jnp.int32)
    return jnp.where(temperature <= 0.0, greedy, sampled)


def _mask_slot_logits(logits, temperature, top_k, k_max):
    """Shared temperature/top-k masking for the per-slot samplers."""
    V = logits.shape[-1]
    k_max = min(k_max, V)
    temp = jnp.maximum(temperature, 1e-6)[:, None]
    scaled = logits.astype(jnp.float32) / temp
    top_vals = jax.lax.top_k(scaled, k_max)[0]
    idx = jnp.clip(top_k - 1, 0, k_max - 1)[:, None]
    cutoff = jnp.take_along_axis(top_vals, idx, axis=-1)
    cutoff = jnp.where((top_k > 0)[:, None], cutoff, -jnp.inf)
    return jnp.where(scaled < cutoff, -jnp.inf, scaled)


def sample_slots_keyed(
    logits: jax.Array,
    temperature: jax.Array,
    top_k: jax.Array,
    keys: jax.Array,
    *,
    k_max: int = 64,
) -> jax.Array:
    """``sample_slots`` with an independent PRNG key per slot.

    keys (B, 2) uint32 — one legacy-format key per slot.  Each slot's draw
    is a function of *its own* key and logits row only, which is what makes
    sampled token streams invariant to scheduling: a request sampled at
    slot 3 on step 40 of a chunked engine draws the same token as at slot 0
    on step 7 of an unchunked one, provided its per-request key chain has
    advanced the same number of times (once per emitted token).
    """
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    masked = _mask_slot_logits(logits, temperature, top_k, k_max)
    sampled = jax.vmap(
        lambda k, row: jax.random.categorical(k, row)
    )(keys, masked).astype(jnp.int32)
    return jnp.where(temperature <= 0.0, greedy, sampled)


def params_as_arrays(params: SamplingParams):
    """(temperature, top_k, eos, max_new) numpy scalars for one slot."""
    return (
        np.float32(params.temperature),
        np.int32(params.top_k),
        np.int32(params.eos_token),
        np.int32(params.max_new_tokens),
    )
