"""Steady-state load generator for the HTTP serving path.

Implements the measurement protocol the serving literature converged on
(vLLM's benchmark serving flow; TokenPowerBench; The Price of Prompting):

1. **Warmup** — drive the server for ``warmup_s`` before measuring, so
   JIT compilation, cache population, and ramp-up never pollute the
   numbers.
2. **Steady-state window** — a fixed ``duration_s`` window; only
   requests *sent* inside it count.  The ``PowerMonitor`` is entered at
   the window's start edge and exited at its end edge, so the monitor's
   ``result()`` total is the energy of exactly the measured window.
3. **Drive modes** — closed-loop (``concurrency`` workers, each sending
   its next request the moment the previous finishes: the server always
   sees N in flight) or open-loop (Poisson arrivals at ``qps``,
   independent of completion times: models real traffic and exposes
   queueing delay that closed-loop hides).
4. **Energy attribution** — the steady-state window is tiled with
   contiguous per-request sub-windows whose widths are proportional to
   completion token counts, and each request's share is
   ``monitor.joules_between`` over its tile.  Because the step-function
   integral is additive over adjacent windows, the shares sum to
   ``monitor.result().joules`` exactly — one ledger, no drift.
"""

from __future__ import annotations

import asyncio
import dataclasses
import time
from typing import Dict, List, Tuple

import numpy as np

from repro.serving.client import ClientRecord, stream_completion

try:
    import aiohttp
except ImportError:  # pragma: no cover - exercised only without aiohttp
    aiohttp = None


@dataclasses.dataclass
class LoadSpec:
    mode: str = "closed"        # "closed" (concurrency-N) | "open" (Poisson)
    concurrency: int = 2        # closed-loop: requests in flight
    qps: float = 4.0            # open-loop: mean Poisson arrival rate
    warmup_s: float = 1.0       # unmeasured ramp before the window
    duration_s: float = 5.0     # steady-state measurement window
    max_requests: int = 10_000  # safety cap across the whole run
    prompt_len: int = 16
    prompt_pool: int = 8        # distinct prompts cycled through
    max_new: int = 16
    temperature: float = 0.0
    top_k: int = 0
    vocab_size: int = 128
    seed: int = 0


@dataclasses.dataclass
class LoadResult:
    records: List[ClientRecord]          # steady-state, error-free
    all_records: List[ClientRecord]      # including warmup / late / errors
    window: Tuple[float, float]          # steady-state [start, end)
    summary: Dict[str, float]


def prewarm_engine(engine, *, prompt_len: int, concurrency: int,
                   vocab_size: int, max_new: int = 4, seed: int = 0) -> None:
    """Compile the executables the load will exercise *before* the server
    starts: prefill at the load's prompt bucket and the step function at
    the load's slot occupancy.  JAX compiles lazily per shape, so without
    this the first requests pay seconds of compile inside the warmup
    phase (or worse, inside the measured window on short runs).  Call it
    before ``start_http_server`` — afterwards the engine thread owns the
    engine."""
    from repro.serving.sampling import SamplingParams

    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, vocab_size, prompt_len).astype(np.int32)
               for _ in range(max(concurrency, 1))]
    # staggered admission: each later request lands while the earlier ones
    # are mid-decode, so the *mixed* prefill+decode step shape compiles
    # too — simultaneous submission would only ever see prefill-only and
    # decode-only steps, leaving a multi-second compile stall for the
    # first staggered arrival of the real load
    engine.submit(prompts[0], SamplingParams(max_new_tokens=max_new))
    for p in prompts[1:]:
        engine.step()
        engine.submit(p, SamplingParams(max_new_tokens=max_new))
    engine.run()


def _percentile(xs: List[float], q: float) -> float:
    """Nearest-rank percentile (same convention as the engine summary)."""
    if not xs:
        return 0.0
    xs = sorted(xs)
    idx = min(int(round(q / 100.0 * (len(xs) - 1))), len(xs) - 1)
    return xs[idx]


def attribute_energy(records: List[ClientRecord], monitor) -> float:
    """Tile ``monitor.window`` with per-request sub-windows proportional
    to completion token counts (ordered by first-chunk time); each
    request's ``joules`` is ``joules_between`` over its tile.  Additivity
    of the step-function integral makes the shares sum to
    ``monitor.result().joules`` exactly."""
    t0, t1 = monitor.window
    ordered = sorted(records, key=lambda r: r.first_chunk_time)
    toks = [len(r.tokens) for r in ordered]
    total = sum(toks)
    if total == 0 or t1 <= t0:
        return 0.0
    attributed = 0.0
    cur = t0
    acc = 0
    for i, (rec, n) in enumerate(zip(ordered, toks)):
        acc += n
        # the last edge lands *exactly* on t1 so the tiles cover the
        # window with shared edges — the precondition for exactness
        nxt = t1 if i == len(ordered) - 1 else t0 + (t1 - t0) * (acc / total)
        rec.joules = monitor.joules_between(cur, nxt)
        attributed += rec.joules
        cur = nxt
    return attributed


def summarize(records: List[ClientRecord], window: Tuple[float, float],
              monitor=None) -> Dict[str, float]:
    ws, we = window
    dur = max(we - ws, 1e-9)
    total_tokens = sum(len(r.tokens) for r in records)
    ttft = [r.client_ttft_s * 1e3 for r in records if r.tokens]
    tpot = [r.client_tpot_s * 1e3 for r in records if len(r.tokens) >= 2]
    ttlt = [r.client_ttlt_s * 1e3 for r in records if r.tokens]
    summary: Dict[str, float] = {
        "steady_requests": float(len(records)),
        "steady_window_s": dur,
        "achieved_qps": len(records) / dur,
        "client_tokens_per_sec": total_tokens / dur,
    }
    for name, xs in (("ttft", ttft), ("tpot", tpot), ("ttlt", ttlt)):
        summary[f"client_{name}_ms"] = float(np.mean(xs)) if xs else 0.0
        summary[f"client_{name}_p50_ms"] = _percentile(xs, 50)
        summary[f"client_{name}_p95_ms"] = _percentile(xs, 95)
    # client-vs-engine deltas: both sides stamp the same monotonic clock,
    # so the delta is the HTTP + submission-queue overhead, always >= 0
    d_ttft = [(r.client_ttft_s - r.engine_ttft_s) * 1e3
              for r in records if r.engine]
    d_tpot = [(r.client_tpot_s - r.engine_tpot_s) * 1e3
              for r in records if r.engine and len(r.tokens) >= 2]
    summary["ttft_client_minus_engine_ms"] = (
        float(np.mean(d_ttft)) if d_ttft else 0.0)
    summary["ttft_client_minus_engine_p95_ms"] = _percentile(d_ttft, 95)
    summary["tpot_client_minus_engine_ms"] = (
        float(np.mean(d_tpot)) if d_tpot else 0.0)
    if monitor is not None:
        res = monitor.result()
        attributed = attribute_energy(records, monitor)
        summary["joules_total"] = res.joules
        summary["joules_attributed"] = attributed
        summary["avg_watts"] = res.avg_watts
        summary["joules_per_request"] = res.joules / max(len(records), 1)
        summary["joules_per_token"] = res.joules / max(total_tokens, 1)
        summary["power_samples_per_sec"] = res.samples_per_sec
        summary["power_reads_dropped"] = float(res.dropped_reads)
    return summary


async def _run_load_async(base_url: str, spec: LoadSpec,
                          monitor=None) -> LoadResult:
    if aiohttp is None:  # pragma: no cover
        raise RuntimeError("aiohttp is required for the load generator")
    rng = np.random.default_rng(spec.seed)
    pool = [rng.integers(0, spec.vocab_size, spec.prompt_len).tolist()
            for _ in range(max(spec.prompt_pool, 1))]
    all_records: List[ClientRecord] = []
    stop = asyncio.Event()
    t_start = time.perf_counter()
    ws = t_start + spec.warmup_s
    we = ws + spec.duration_s
    window_open: List[float] = [ws, we]  # actual monitor edges

    async def phase_clock() -> None:
        # the monitor brackets exactly the steady-state window, so the
        # run total and the per-request tiles share the same [t0, t1]
        await asyncio.sleep(max(ws - time.perf_counter(), 0.0))
        if monitor is not None:
            monitor.__enter__()
            window_open[0] = monitor.window[0]
        await asyncio.sleep(max(we - time.perf_counter(), 0.0))
        if monitor is not None:
            monitor.__exit__(None, None, None)
            window_open[1] = monitor.window[1]
        stop.set()

    async def one(idx: int, session) -> None:
        rec = await stream_completion(
            session, base_url, pool[idx % len(pool)],
            max_tokens=spec.max_new, temperature=spec.temperature,
            top_k=spec.top_k)
        all_records.append(rec)

    async def closed_worker(wid: int, session) -> None:
        i = 0
        while not stop.is_set() and len(all_records) < spec.max_requests:
            await one(wid + i * spec.concurrency, session)
            i += 1

    async def open_driver(session) -> None:
        tasks = []
        t = t_start
        k = 0
        while k < spec.max_requests:
            t += float(rng.exponential(1.0 / max(spec.qps, 1e-9)))
            if t >= we:
                break
            await asyncio.sleep(max(t - time.perf_counter(), 0.0))
            if stop.is_set():
                break
            tasks.append(asyncio.create_task(one(k, session)))
            k += 1
        if tasks:
            await asyncio.gather(*tasks)

    clock = asyncio.create_task(phase_clock())
    async with aiohttp.ClientSession() as session:
        if spec.mode == "open":
            await open_driver(session)
        else:
            await asyncio.gather(*(closed_worker(w, session)
                                   for w in range(spec.concurrency)))
    await clock

    w0, w1 = window_open
    steady = [r for r in all_records
              if not r.error and w0 <= r.send_time < w1]
    summary = summarize(steady, (w0, w1), monitor=monitor)
    summary["warmup_excluded"] = float(
        sum(1 for r in all_records if r.send_time < w0))
    summary["errors"] = float(sum(1 for r in all_records if r.error))
    return LoadResult(records=steady, all_records=all_records,
                      window=(w0, w1), summary=summary)


def run_load(base_url: str, spec: LoadSpec,
             monitor=None) -> LoadResult:
    """Blocking entry point: drive ``base_url`` per ``spec``; if a
    ``PowerMonitor`` is given it is entered/exited at the steady-state
    window edges and the summary carries the energy ledger."""
    return asyncio.run(_run_load_async(base_url, spec, monitor=monitor))
