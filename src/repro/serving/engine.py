"""Batched serving engine: request queue, slot-based continuous batching,
prefill + decode loops, per-request latency accounting (TTFT/TPOT/TTLT).

Design (vLLM-lite, static-shape TPU-friendly):
  * fixed ``max_batch`` decode slots; the decode executable is compiled once
    for (max_batch, max_len) and replayed every step (the paper's
    CUDA-graph-cached generation, in jit form);
  * waiting requests are admitted whenever a slot frees, their prompt is
    prefilled into the slot's cache region at a bucketed prompt length;
  * per-slot position counters + an active mask keep finished slots inert
    (they decode garbage into their own slot only) until replaced.

Because each slot's KV lives in the same cache pytree, admission writes the
newly prefilled slot into the batched cache via ``dynamic_update_slice``.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as model_lib
from repro.models.config import ModelConfig
from repro.serving.sampling import SamplingParams, sample


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # (prompt_len,) int32
    params: SamplingParams = SamplingParams()
    # filled by the engine:
    submit_time: float = 0.0
    first_token_time: float = 0.0
    finish_time: float = 0.0
    output_tokens: List[int] = dataclasses.field(default_factory=list)

    @property
    def ttft_s(self) -> float:
        return self.first_token_time - self.submit_time

    @property
    def ttlt_s(self) -> float:
        return self.finish_time - self.submit_time

    @property
    def tpot_s(self) -> float:
        n = max(len(self.output_tokens) - 1, 1)
        return (self.finish_time - self.first_token_time) / n


class ServingEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        max_batch: int = 4,
        max_len: int = 512,
        prompt_bucket: int = 32,
        seed: int = 0,
    ):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.prompt_bucket = prompt_bucket
        self.key = jax.random.PRNGKey(seed)
        dtype = jnp.dtype(cfg.dtype)
        self.cache = model_lib.init_cache(cfg, max_batch, max_len, dtype)
        # one-slot prefill cache template (prefill runs at batch=1 per admit)
        self._slot_cache_tmpl = model_lib.init_cache(cfg, 1, max_len, dtype)
        self.positions = np.zeros(max_batch, np.int64)
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.queue: deque = deque()
        self.finished: List[Request] = []
        self._next_tokens = np.zeros((max_batch, 1), np.int32)
        self._uid = 0

        self._prefill = jax.jit(
            lambda p, batch, cache: model_lib.prefill(cfg, p, batch, cache))
        self._decode = jax.jit(
            lambda p, tok, pos, cache: model_lib.decode_step(cfg, p, tok, pos, cache))

    # -- public API -----------------------------------------------------------
    def submit(self, prompt: np.ndarray,
               params: Optional[SamplingParams] = None) -> int:
        req = Request(uid=self._uid, prompt=np.asarray(prompt, np.int32),
                      params=params or SamplingParams())
        req.submit_time = time.perf_counter()
        self._uid += 1
        self.queue.append(req)
        return req.uid

    def run(self, max_steps: int = 10_000) -> List[Request]:
        """Drive until queue + slots drain (or step budget); returns finished."""
        steps = 0
        while (self.queue or any(s is not None for s in self.slots)) \
                and steps < max_steps:
            self._admit()
            self._decode_once()
            steps += 1
        return self.finished

    # -- internals --------------------------------------------------------------
    def _bucketed(self, n: int) -> int:
        b = self.prompt_bucket
        return min(self.max_len - 1, ((n + b - 1) // b) * b)

    def _admit(self) -> None:
        for slot in range(self.max_batch):
            if self.slots[slot] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            plen = self._bucketed(len(req.prompt))
            toks = np.zeros((1, plen), np.int32)
            toks[0, -len(req.prompt):] = req.prompt[: plen]
            batch = {"tokens": jnp.asarray(toks)}
            if self.cfg.is_encdec:
                batch["enc_embeds"] = jnp.zeros(
                    (1, max(plen // 2, 1), self.cfg.d_model), jnp.dtype(self.cfg.dtype))
            if self.cfg.num_vision_tokens:
                batch["vision_embeds"] = jnp.zeros(
                    (1, self.cfg.num_vision_tokens, self.cfg.d_model),
                    jnp.dtype(self.cfg.dtype))
            logits, slot_cache = self._prefill(
                self.params, batch, self._slot_cache_tmpl)
            self.cache = self._merge_slot_cache(self.cache, slot_cache, slot)
            self.key, k = jax.random.split(self.key)
            tok = sample(logits, req.params, k)
            req.first_token_time = time.perf_counter()
            req.output_tokens.append(int(tok[0]))
            self._next_tokens[slot, 0] = int(tok[0])
            self.positions[slot] = plen
            self.slots[slot] = req
            self._maybe_finish(slot)

    @staticmethod
    def _merge_slot_cache(full_cache, slot_cache, slot: int):
        """Write a freshly prefilled single-slot cache into decode slot ``slot``.

        Cache leaves under ``groups`` carry a leading scan-group axis, so the
        batch dim is axis 1 there and axis 0 under ``rest``.
        """

        def upd(axis):
            def fn(full, one):
                if full.ndim <= axis:
                    return full  # scalars / shared bookkeeping (e.g. `ring`)
                return jax.lax.dynamic_update_slice_in_dim(
                    full, one.astype(full.dtype), slot, axis=axis)

            return fn

        merged = {}
        if "groups" in full_cache:
            merged["groups"] = jax.tree.map(
                upd(1), full_cache["groups"], slot_cache["groups"])
        if "rest" in full_cache:
            merged["rest"] = jax.tree.map(
                upd(0), full_cache["rest"], slot_cache["rest"])
        return merged

    def _decode_once(self) -> None:
        if not any(s is not None for s in self.slots):
            return
        tok = jnp.asarray(self._next_tokens)
        pos_vec = jnp.asarray(self.positions, jnp.int32)  # per-slot positions
        logits, self.cache = self._decode(self.params, tok, pos_vec, self.cache)
        self.key, k = jax.random.split(self.key)
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            t = sample(logits[slot:slot + 1], req.params,
                       jax.random.fold_in(k, slot))
            req.output_tokens.append(int(t[0]))
            self._next_tokens[slot, 0] = int(t[0])
            self.positions[slot] += 1
            self._maybe_finish(slot)

    def _maybe_finish(self, slot: int) -> None:
        req = self.slots[slot]
        if req is None:
            return
        done = len(req.output_tokens) >= req.params.max_new_tokens
        if req.params.eos_token >= 0 and req.output_tokens and \
                req.output_tokens[-1] == req.params.eos_token:
            done = True
        if self.positions[slot] >= self.max_len - 1:
            done = True
        if done:
            req.finish_time = time.perf_counter()
            self.finished.append(req)
            self.slots[slot] = None

    # -- metrics -----------------------------------------------------------------
    def latency_summary(self) -> Dict[str, float]:
        if not self.finished:
            return {}
        ttfts = [r.ttft_s for r in self.finished]
        tpots = [r.tpot_s for r in self.finished]
        ttlts = [r.ttlt_s for r in self.finished]
        mean = lambda xs: sum(xs) / len(xs)
        return {
            "requests": len(self.finished),
            "ttft_ms": mean(ttfts) * 1e3,
            "tpot_ms": mean(tpots) * 1e3,
            "ttlt_ms": mean(ttlts) * 1e3,
        }
