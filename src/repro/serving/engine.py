"""Device-resident continuous-batching serving engine.

Design (vLLM-lite, static-shape TPU-friendly):

* **One fused jitted step** (``serving.step.make_decode_sample_step``)
  performs decode forward + per-slot sampling + finish detection.  All
  per-slot scheduler state — next tokens, positions, active mask, sampling
  params (temperature / top-k / EOS), remaining-token budgets, and the PRNG
  key — lives on device and threads through the step without touching the
  host.  The executable is compiled once for (max_batch, max_len) and
  replayed every step (the paper's CUDA-graph-cached generation, in jit
  form).
* **One host sync per step.**  The step returns a packed (3, B) int32 array
  (token, done-flag, emitted-flag per slot); the host fetches it with a
  single transfer and appends the token vector to a numpy ring buffer.  No
  ``int(t[0])`` per slot, no per-slot sampling dispatches.
* **Continuous batching.**  Waiting requests are admitted whenever a slot
  frees; their prompt is prefilled at a bucketed length (batch=1) and the
  resulting KV written into the batched cache via ``dynamic_update_slice``.
  Admission updates the device state with O(1)-sized ``.at[slot].set``
  writes — lazy device ops, not syncs.  Prompts longer than ``max_len - 1``
  keep their *last* ``plen`` tokens and are flagged ``truncated``.
* **Open-loop friendly.**  ``step()`` performs one admit+decode round so a
  traffic driver (``serving.workload``) can interleave Poisson arrivals
  with engine work; ``run()`` is the closed-loop drain used by tests.
* **Per-request energy attribution.**  With a ``core.energy.PowerMonitor``
  attached, the engine tiles wall-clock into windows (closed whenever a
  request finishes and at drain); each window's joules — step-function
  integral over the monitor's samples, exactly additive across windows —
  are split over the requests proportionally to the tokens they emitted in
  that window and accumulated on ``Request.joules``.

Follow-on work (paged KV, chunked prefill) is tracked in ROADMAP.md
§Serving.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.energy import PowerMonitor
from repro.models import model as model_lib
from repro.models.config import ModelConfig
from repro.serving.sampling import SamplingParams, sample
from repro.serving.step import init_slot_state, make_decode_sample_step

_RING = 64  # host-side token ring buffer depth (tokens per slot per flush)


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # (prompt_len,) int32
    params: SamplingParams = SamplingParams()
    # filled by the engine:
    submit_time: float = 0.0
    first_token_time: float = 0.0
    finish_time: float = 0.0
    output_tokens: List[int] = dataclasses.field(default_factory=list)
    truncated: bool = False
    joules: float = 0.0

    @property
    def ttft_s(self) -> float:
        return self.first_token_time - self.submit_time

    @property
    def ttlt_s(self) -> float:
        return self.finish_time - self.submit_time

    @property
    def tpot_s(self) -> float:
        n = max(len(self.output_tokens) - 1, 1)
        return (self.finish_time - self.first_token_time) / n


def _percentile(xs: List[float], q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]) of a non-empty list."""
    ys = sorted(xs)
    k = max(int(np.ceil(len(ys) * q / 100.0)), 1) - 1
    return ys[min(k, len(ys) - 1)]


class ServingEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        max_batch: int = 4,
        max_len: int = 512,
        prompt_bucket: int = 32,
        seed: int = 0,
        monitor: Optional[PowerMonitor] = None,
        top_k_max: int = 64,
    ):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.prompt_bucket = prompt_bucket
        # static bound on per-request top-k inside the fused step (a full
        # per-slot vocab sort would dominate it); requests asking for more
        # are clamped — consistently, first token included
        self.top_k_max = min(top_k_max, cfg.vocab_size)
        self.key = jax.random.PRNGKey(seed)  # host-side key for prefill sampling
        dtype = jnp.dtype(cfg.dtype)
        self.cache = model_lib.init_cache(cfg, max_batch, max_len, dtype)
        # one-slot prefill cache template (prefill runs at batch=1 per admit)
        self._slot_cache_tmpl = model_lib.init_cache(cfg, 1, max_len, dtype)
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.queue: deque = deque()
        self.finished: List[Request] = []
        self._uid = 0

        # device-resident scheduler state + fused step
        self._state = init_slot_state(max_batch, seed=seed + 1)
        self._step = jax.jit(
            make_decode_sample_step(cfg, max_len, k_max=self.top_k_max))
        self._prefill = jax.jit(
            lambda p, batch, cache: model_lib.prefill(cfg, p, batch, cache))

        # host-side token ring buffer: (max_batch, _RING) plus fill counts
        self._ring = np.zeros((max_batch, _RING), np.int32)
        self._ring_n = np.zeros(max_batch, np.int64)

        # energy attribution
        self.monitor = monitor
        self._win_t0: Optional[float] = None
        self._win_tokens: Dict[int, int] = {}
        self.attributed_joules = 0.0

    # -- public API -----------------------------------------------------------
    def submit(self, prompt: np.ndarray,
               params: Optional[SamplingParams] = None) -> int:
        params = params or SamplingParams()
        if params.top_k > self.top_k_max:
            params = dataclasses.replace(params, top_k=self.top_k_max)
        req = Request(uid=self._uid, prompt=np.asarray(prompt, np.int32),
                      params=params)
        req.submit_time = time.perf_counter()
        self._uid += 1
        self.queue.append(req)
        return req.uid

    @property
    def busy(self) -> bool:
        return bool(self.queue) or any(s is not None for s in self.slots)

    def step(self) -> bool:
        """One admit + decode round; returns True if any work was done."""
        if not self.busy:
            return False
        self._admit()
        self._decode_once()
        return True

    def run(self, max_steps: int = 10_000) -> List[Request]:
        """Drive until queue + slots drain (or step budget); returns finished."""
        steps = 0
        while self.busy and steps < max_steps:
            self.step()
            steps += 1
        self.flush()
        return self.finished

    def flush(self) -> None:
        """Drain host-side buffers: ring-buffered tokens of still-running
        requests (so ``output_tokens`` is complete even on a step-budget
        exit) and the open energy-attribution window."""
        for slot in range(self.max_batch):
            self._flush_ring(slot)
        self._flush_energy()

    def attach_monitor(self, monitor: PowerMonitor) -> None:
        """Start attributing the monitor's energy to requests from now on."""
        self.monitor = monitor
        self._win_t0 = None
        self._win_tokens = {}


    # -- internals --------------------------------------------------------------
    def _bucketed(self, n: int) -> int:
        b = self.prompt_bucket
        return min(self.max_len - 1, ((n + b - 1) // b) * b)

    def _admit(self) -> None:
        for slot in range(self.max_batch):
            if self.slots[slot] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            plen = self._bucketed(len(req.prompt))
            use = req.prompt
            if len(use) > plen:  # keep the newest context, flag the loss
                use = use[-plen:]
                req.truncated = True
            toks = np.zeros((1, plen), np.int32)
            toks[0, -len(use):] = use
            batch = {"tokens": jnp.asarray(toks)}
            if self.cfg.is_encdec:
                batch["enc_embeds"] = jnp.zeros(
                    (1, max(plen // 2, 1), self.cfg.d_model), jnp.dtype(self.cfg.dtype))
            if self.cfg.num_vision_tokens:
                batch["vision_embeds"] = jnp.zeros(
                    (1, self.cfg.num_vision_tokens, self.cfg.d_model),
                    jnp.dtype(self.cfg.dtype))
            logits, slot_cache = self._prefill(
                self.params, batch, self._slot_cache_tmpl)
            self.cache = self._merge_slot_cache(self.cache, slot_cache, slot)
            self.key, k = jax.random.split(self.key)
            first = int(sample(logits, req.params, k)[0])
            req.first_token_time = time.perf_counter()
            req.output_tokens.append(first)
            self.slots[slot] = req
            self._count_token(req)

            done = (req.params.max_new_tokens <= 1
                    or (req.params.eos_token >= 0
                        and first == req.params.eos_token)
                    or plen >= self.max_len - 1)
            self._write_slot_state(
                slot, token=first, position=plen,
                remaining=req.params.max_new_tokens - 1,
                params=req.params, active=not done)
            if done:
                self._finish(slot)

    def _write_slot_state(self, slot: int, *, token: int, position: int,
                          remaining: int, params: SamplingParams,
                          active: bool) -> None:
        """Admission-time write of one slot's device state (lazy device ops)."""
        s = self._state
        s["tokens"] = s["tokens"].at[slot, 0].set(token)
        s["positions"] = s["positions"].at[slot].set(position)
        s["remaining"] = s["remaining"].at[slot].set(remaining)
        s["temperature"] = s["temperature"].at[slot].set(params.temperature)
        s["top_k"] = s["top_k"].at[slot].set(params.top_k)
        s["eos"] = s["eos"].at[slot].set(params.eos_token)
        s["active"] = s["active"].at[slot].set(active)

    @staticmethod
    def _merge_slot_cache(full_cache, slot_cache, slot: int):
        """Write a freshly prefilled single-slot cache into decode slot ``slot``.

        Cache leaves under ``groups`` carry a leading scan-group axis, so the
        batch dim is axis 1 there and axis 0 under ``rest``.
        """

        def upd(axis):
            def fn(full, one):
                if full.ndim <= axis:
                    return full  # scalars / shared bookkeeping (e.g. `ring`)
                return jax.lax.dynamic_update_slice_in_dim(
                    full, one.astype(full.dtype), slot, axis=axis)

            return fn

        merged = {}
        if "groups" in full_cache:
            merged["groups"] = jax.tree.map(
                upd(1), full_cache["groups"], slot_cache["groups"])
        if "rest" in full_cache:
            merged["rest"] = jax.tree.map(
                upd(0), full_cache["rest"], slot_cache["rest"])
        return merged

    def _decode_once(self) -> None:
        if not any(s is not None for s in self.slots):
            return
        self._state, self.cache, out = self._step(
            self.params, self._state, self.cache)
        out_np = np.asarray(out)  # the single host<->device sync per step
        tokens, done, emitted = out_np[0], out_np[1], out_np[2]
        for slot in np.nonzero(emitted)[0]:
            req = self.slots[slot]
            if req is None:
                continue  # stale flag for a slot freed on the host side
            n = int(self._ring_n[slot])
            self._ring[slot, n] = tokens[slot]
            self._ring_n[slot] = n + 1
            if n + 1 == _RING:
                self._flush_ring(slot)
            self._count_token(req)
            if done[slot]:
                self._finish(slot)

    def _flush_ring(self, slot: int) -> None:
        n = int(self._ring_n[slot])
        req = self.slots[slot]
        if req is not None and n:
            req.output_tokens.extend(int(t) for t in self._ring[slot, :n])
        self._ring_n[slot] = 0

    def _finish(self, slot: int) -> None:
        req = self.slots[slot]
        if req is None:
            return
        self._flush_ring(slot)
        req.finish_time = time.perf_counter()
        self.finished.append(req)
        self.slots[slot] = None
        # state["active"] already cleared on device by the fused step for
        # decode finishes; clear explicitly for admission-time finishes
        self._state["active"] = self._state["active"].at[slot].set(False)
        self._flush_energy()

    # -- energy attribution ------------------------------------------------------
    def _count_token(self, req: Request) -> None:
        if self.monitor is None:
            return
        if self._win_t0 is None:
            t0 = self.monitor.window[0]
            self._win_t0 = t0 if t0 > 0.0 else time.perf_counter()
        self._win_tokens[req.uid] = self._win_tokens.get(req.uid, 0) + 1

    def _flush_energy(self) -> None:
        """Close the current window: split its joules by token counts."""
        if self.monitor is None or self._win_t0 is None:
            return
        t1 = time.perf_counter()
        joules = self.monitor.joules_between(self._win_t0, t1)
        total = sum(self._win_tokens.values())
        if total > 0 and joules > 0.0:
            by_uid = {r.uid: r for r in self.finished}
            for s in self.slots:
                if s is not None:
                    by_uid[s.uid] = s
            for uid, n in self._win_tokens.items():
                share = joules * n / total
                if uid in by_uid:
                    by_uid[uid].joules += share
                self.attributed_joules += share
        self._win_t0 = t1
        self._win_tokens = {}

    # -- metrics -----------------------------------------------------------------
    def latency_summary(self) -> Dict[str, float]:
        if not self.finished:
            return {}
        ttfts = [r.ttft_s for r in self.finished]
        tpots = [r.tpot_s for r in self.finished]
        ttlts = [r.ttlt_s for r in self.finished]
        mean = lambda xs: sum(xs) / len(xs)
        out_tokens = sum(len(r.output_tokens) for r in self.finished)
        t_first = min(r.submit_time for r in self.finished)
        t_last = max(r.finish_time for r in self.finished)
        span = max(t_last - t_first, 1e-9)
        summary = {
            "requests": len(self.finished),
            "truncated": sum(1 for r in self.finished if r.truncated),
            "output_tokens": out_tokens,
            "tokens_per_sec": out_tokens / span,
            "ttft_ms": mean(ttfts) * 1e3,
            "tpot_ms": mean(tpots) * 1e3,
            "ttlt_ms": mean(ttlts) * 1e3,
        }
        for name, xs in (("ttft", ttfts), ("tpot", tpots), ("ttlt", ttlts)):
            for q in (50, 95, 99):
                summary[f"{name}_p{q}_ms"] = _percentile(xs, q) * 1e3
        if self.monitor is not None:
            total_j = sum(r.joules for r in self.finished)
            summary["joules_total"] = total_j
            summary["joules_per_request"] = total_j / len(self.finished)
            summary["joules_per_token"] = total_j / max(out_tokens, 1)
        return summary
