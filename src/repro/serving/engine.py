"""Device-resident continuous-batching serving engine (vLLM-lite).

One fused jitted step (decode + per-slot sampling + finish detection, one
host sync per step), a paged block-pool KV cache with a host-managed free
stack, batched multi-slot admission, Sarathi-style chunked prefill, and
block-level prefix caching (``prefix_cache=True``): full prompt blocks are
content-hashed and shared read-only across requests through refcounts, so
a request whose prefix is already resident skips straight to its first
non-cached block.  With ``preemption="recompute"`` the engine stays
correct under pool *overcommit*: blocks are reserved lazily and grown as
decodes cross block boundaries, and when the pool runs dry the newest
admitted request (never the head-of-line) is preempted — its private
blocks freed, the request parked — and later re-admitted by recomputing
its prompt + generated-so-far prefix through the chunked-prefill path.
Sampling is scheduling-invariant (per-request PRNG chains, restored
exactly on resume), so every layout/scheduling/preemption combination
emits byte-identical token streams for the same seed.

The full design guide — request lifecycle, pool/refcount bookkeeping, and
the invariants the test suites hold — lives in ``docs/serving.md``.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.energy import PowerMonitor
from repro.models import cache as cache_lib
from repro.models import model as model_lib
from repro.models.config import ModelConfig
from repro.sharding import partition as partition_lib
from repro.sharding import rules as rules_lib
from repro.serving.sampling import SamplingParams, sample
from repro.serving.step import (init_slot_state, invalidate_slot,
                                make_decode_sample_step, make_engine_step,
                                make_spec_decode_step, maybe_donate)

_RING = 64  # host-side token ring buffer depth (tokens per slot per flush)


def prompt_lookup_draft(hist: List[int], k: int,
                        ngram_max: int = 3) -> List[int]:
    """Draft-free speculative drafting by prompt lookup: propose the ``k``
    tokens that followed an earlier occurrence of the stream's trailing
    n-gram (longest n first, ``ngram_max`` down to 1).

    Among the occurrences of the longest matching n-gram, the most recent
    one with a full ``k``-token continuation wins (recent context best
    predicts a loop or a template being re-instantiated); if every match
    sits too close to the end for ``k`` tokens, the longest available
    continuation wins instead.  Returns ``[]`` when nothing matches — the
    verify step then degrades to a plain one-token decode.  Draft content
    only ever affects how many tokens a verify dispatch may emit, never
    *which* tokens, so this heuristic is pure performance tuning."""
    L = len(hist)
    if k <= 0 or L < 2:
        return []
    for n in range(min(ngram_max, L - 1), 0, -1):
        pat = hist[L - n:]
        best = None  # (continuation length, match index)
        for i in range(L - n - 1, -1, -1):
            if hist[i:i + n] == pat:
                c = min(k, L - i - n)
                if c == k:
                    best = (c, i)
                    break
                if best is None or c > best[0]:
                    best = (c, i)
        if best is not None:
            c, i = best
            return hist[i + n:i + n + c]
    return []


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # (prompt_len,) int32
    # default_factory: a shared default instance would alias any future
    # mutable sampling fields across every request that omitted params
    params: SamplingParams = dataclasses.field(default_factory=SamplingParams)
    # filled by the engine:
    submit_time: float = 0.0
    first_token_time: float = 0.0
    finish_time: float = 0.0
    output_tokens: List[int] = dataclasses.field(default_factory=list)
    truncated: bool = False
    joules: float = 0.0
    # preemption priority: order of *first* admission (kept across
    # re-admissions so the oldest in-flight request — the head-of-line —
    # is stable and can never be picked as a victim); -1 = never admitted
    admit_seq: int = -1
    preemptions: int = 0
    # memoized (plen, block hashes) — the prompt and its bucket never
    # change, and admission may probe a backpressured request every step
    _hash_cache: Optional[tuple] = dataclasses.field(
        default=None, repr=False, compare=False)

    @property
    def ttft_s(self) -> float:
        return self.first_token_time - self.submit_time

    @property
    def ttlt_s(self) -> float:
        return self.finish_time - self.submit_time

    @property
    def tpot_s(self) -> float:
        # a request that emitted <= 1 token has no inter-token interval,
        # and one that never started/finished has meaningless timestamps —
        # report 0.0 instead of dividing into garbage
        n = len(self.output_tokens) - 1
        if n <= 0 or self.finish_time <= self.first_token_time:
            return 0.0
        return (self.finish_time - self.first_token_time) / n


def _percentile(xs: List[float], q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]); 0.0 for an empty list."""
    if not xs:
        return 0.0
    ys = sorted(xs)
    k = max(int(np.ceil(len(ys) * q / 100.0)), 1) - 1
    return ys[min(k, len(ys) - 1)]


@dataclasses.dataclass
class _PrefillCursor:
    """Per-slot chunked-prefill progress (the third scheduler state)."""
    req: Request
    tokens: np.ndarray            # (plen,) bucketed, left-padded prompt
    plen: int                     # bucketed prompt length
    next: int = 0                 # next prompt position to prefill
    tables_np: Optional[np.ndarray] = None  # (max_blocks,) paged table row
    # prefix cache: (end position, block) pairs this cursor registered;
    # each block is marked ready once the cursor passes its end
    pending_ready: List = dataclasses.field(default_factory=list)
    # preemption recompute: number of tokens the request had already
    # emitted when it was preempted.  0 = a fresh admission (sample the
    # first token from the final chunk's logits); > 0 = a resumed request
    # (the next token is already known — re-arm the slot instead)
    resume_n: int = 0


class ServingEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        max_batch: int = 4,
        max_len: int = 512,
        prompt_bucket: int = 32,
        seed: int = 0,
        monitor: Optional[PowerMonitor] = None,
        top_k_max: int = 64,
        cache_layout: str = "contiguous",
        kv_block_size: int = 16,
        kv_num_blocks: int = 0,
        prefill_chunk: int = 0,
        prefill_budget: int = 0,
        prefix_cache: bool = False,
        preemption: str = "off",
        unified_step: bool = True,
        pad_side: str = "left",
        speculative: str = "off",
        spec_tokens: int = 4,
        mesh=None,
        shard_rules=None,
        param_axes=None,
    ):
        assert cache_layout in ("contiguous", "paged"), cache_layout
        assert preemption in ("off", "recompute"), preemption
        assert pad_side in ("left", "right"), pad_side
        if speculative not in ("off", "lookup"):
            raise ValueError(
                f"speculative must be 'off' or 'lookup', got {speculative!r}")
        self.speculative = speculative
        self.spec_k = int(spec_tokens) if speculative != "off" else 0
        if speculative != "off":
            if self.spec_k < 1:
                raise ValueError(
                    f"--spec-tokens={spec_tokens} must be >= 1 when "
                    f"--speculative is on")
            bad = sorted({k for k in cfg.blocks() if k not in ("attn", "ffn")})
            if bad or cfg.is_encdec or cfg.num_vision_tokens:
                raise ValueError(
                    f"speculative='lookup' relies on rejected draft "
                    f"suffixes being re-writable cache positions, which "
                    f"only full-attention KV supports; {cfg.name!r} "
                    f"carries per-slot state that cannot rewind "
                    f"({', '.join(bad) or 'cross-attention/vision prefix'})")
        if pad_side == "right" and (cfg.is_encdec or cfg.num_vision_tokens):
            raise ValueError(
                f"pad_side='right' realigns the bucketed prompt row so "
                f"variable-length suffixes of a shared prefix land on the "
                f"same block boundaries; {cfg.name!r} carries an "
                f"encoder/vision prefix whose position bookkeeping assumes "
                f"the whole padded row is computed")
        self.pad_side = pad_side
        if preemption != "off":
            if cache_layout != "paged":
                raise ValueError(
                    "preemption requires cache_layout='paged': only a block "
                    "pool can run dry mid-decode and reclaim a victim's "
                    "blocks")
            if cfg.is_encdec or cfg.num_vision_tokens:
                raise ValueError(
                    f"preemption='recompute' replays a request's prompt + "
                    f"generated tokens through the chunked-prefill path; "
                    f"{cfg.name!r} carries an encoder/vision prefix whose "
                    f"replay length would differ from the original "
                    f"admission")
        self.preemption = preemption
        if prefix_cache:
            if cache_layout != "paged":
                raise ValueError(
                    "prefix_cache requires cache_layout='paged': only pool "
                    "blocks can be shared read-only across requests")
            bad = sorted({k for k in cfg.blocks() if k not in ("attn", "ffn")})
            if bad or cfg.is_encdec or cfg.num_vision_tokens:
                raise ValueError(
                    f"prefix_cache shares paged full-attention KV blocks "
                    f"only; {cfg.name!r} carries per-slot state that a "
                    f"skipped prefill would leave stale "
                    f"({', '.join(bad) or 'cross-attention/vision prefix'})")
        self.prefix_cache = prefix_cache
        self.cfg = cfg
        # tensor-parallel serving: an engine-owned mesh makes every jitted
        # trace/dispatch run under ``use_mesh`` (see ``_counted``), so the
        # model code's logical-axis ``shard`` constraints resolve against
        # it.  Heads/FFN shard over the ``tp`` axis; slot state replicates,
        # keeping the packed per-step host sync one transfer.
        self._mesh = mesh
        self._rules = shard_rules if shard_rules is not None else (
            rules_lib.TP_SERVE_RULES if mesh is not None else None)
        if mesh is not None and param_axes is not None:
            params = jax.device_put(params, partition_lib.param_shardings(
                param_axes, params, mesh, self._rules))
        elif mesh is not None:
            params = jax.device_put(params, partition_lib.replicated(mesh))
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.prompt_bucket = prompt_bucket
        self.layout = cache_layout
        # chunked prefill: 0 disables (whole-prompt admission); the budget
        # is prompt tokens of chunk work per engine step (default: one
        # chunk).  Clamped to >= one chunk — a smaller budget would never
        # fit the head cursor's next chunk and stall its request forever.
        self.chunk = max(int(prefill_chunk), 0)
        self.chunk_budget = max(int(prefill_budget) or self.chunk, self.chunk)
        # static bound on per-request top-k inside the fused step (a full
        # per-slot vocab sort would dominate it); requests asking for more
        # are clamped — consistently, first token included
        self.top_k_max = min(top_k_max, cfg.vocab_size)
        # per-request sampling keys derive from this by uid (fold_in), so
        # streams do not depend on admission scheduling
        self._base_key = jax.random.PRNGKey(seed)
        dtype = jnp.dtype(cfg.dtype)
        self._dtype = dtype

        # paged block-pool bookkeeping (host-managed free stack)
        self.block_size = kv_block_size
        self.max_blocks_per_slot = cache_lib.blocks_per_slot(max_len, kv_block_size)
        if cache_layout == "paged":
            self.num_blocks = kv_num_blocks or cache_lib.default_num_blocks(
                max_batch, max_len, kv_block_size)
            min_blocks = self.max_blocks_per_slot + 1
            if self.num_blocks < min_blocks:
                raise ValueError(
                    f"--kv-num-blocks={self.num_blocks} is too small: "
                    f"max_len={max_len} at block size {kv_block_size} needs "
                    f"{self.max_blocks_per_slot} blocks for one worst-case "
                    f"request, plus the reserved garbage block 0 — pass "
                    f"--kv-num-blocks >= {min_blocks} (or 0 for the "
                    f"worst-case default of "
                    f"{cache_lib.default_num_blocks(max_batch, max_len, kv_block_size)})")
            self._pool = cache_lib.BlockPool(self.num_blocks)
        else:
            self.num_blocks = 0
            self._pool = cache_lib.BlockPool(1)  # empty pool, no free blocks
        self._slot_blocks: List[List[int]] = [[] for _ in range(max_batch)]
        self.peak_blocks_in_use = 0
        # prefix-cache counters (reported by latency_summary)
        self.prefix_lookups = 0
        self.prefix_hits = 0
        self.prefix_blocks_reused = 0
        self.prefill_tokens_skipped = 0
        # preemption: parked requests (sorted by admit_seq — re-admitted
        # oldest-first, and always ahead of the waiting queue), counters,
        # the host mirror of each decoding slot's next write position
        # (drives decode-time block growth), and per-step pool-occupancy
        # samples for the latency_summary percentiles
        self._preempted: List[Request] = []
        self._admit_seq = 0
        self.preemptions = 0
        self.recompute_tokens = 0
        self._next_pos = np.zeros(max_batch, np.int64)
        self._occ_samples: List[float] = []
        # device-dispatch accounting: every jitted callable is wrapped by
        # ``_counted`` so ``_dispatches`` counts executable launches; the
        # per-step deltas feed the dispatches_per_step percentiles
        self._dispatches = 0
        self._dispatch_samples: List[int] = []
        # decode-side economics: device-emitted decode tokens over the
        # dispatches that carried them (speculation pushes the ratio past
        # the batch size), plus drafter accounting for the accept rate
        self._decode_tokens = 0
        self._decode_dispatches = 0
        self._drafted_tokens = 0
        self._accepted_tokens = 0
        self._spec_verifies = 0
        # host mirror of each slot's uploaded draft length: block growth
        # must cover the verify window's cache writes, not just next_pos
        self._draft_len_host = np.zeros(max_batch, np.int64)
        self._steps_done = 0
        self._steps_t0: Optional[float] = None
        self._steps_t1 = 0.0
        # PRNG chain fast-forward for resume: n rides as a traced scalar,
        # so restoring a chain is one dispatch regardless of how many
        # tokens the parked request had emitted
        self._advance_chain = self._counted(jax.jit(
            lambda key, n: jax.lax.fori_loop(
                0, n, lambda _, k: jax.random.split(k)[1], key)))

        self.cache = model_lib.init_cache(
            cfg, max_batch, max_len, dtype, layout=cache_layout,
            block_size=kv_block_size, num_blocks=self.num_blocks)
        if mesh is not None:
            # KV shards live on their device: heads-sharded pool/cache rows
            # (block axes never shard — the host-managed tables index every
            # device's pool identically)
            self.cache = jax.device_put(
                self.cache, partition_lib.cache_shardings(self.cache, mesh))
        self.slots: List[Optional[Request]] = [None] * max_batch
        # chunked-prefill cursors: _cursors[s] is set while slot s is in the
        # *prefilling* state; _prefill_order is the FCFS service order
        self._cursors: List[Optional[_PrefillCursor]] = [None] * max_batch
        self._prefill_order: List[int] = []
        # slot rows admitted this step, reset in one batched dispatch
        self._pending_reset: List[int] = []
        self.queue: deque = deque()
        self.finished: List[Request] = []
        self._uid = 0

        # device-resident scheduler state + fused step (cache/state donated
        # into the step on backends that support it)
        self._state = init_slot_state(
            max_batch, seed=seed + 1,
            max_blocks=self.max_blocks_per_slot if cache_layout == "paged" else 0,
            spec_k=self.spec_k)
        if mesh is not None:
            # per-slot sampling/PRNG state replicates across the mesh so the
            # packed host sync stays a single fully-replicated transfer
            self._state = jax.device_put(
                self._state, partition_lib.replicated(mesh))
        if self.spec_k:
            self._step = self._counted(maybe_donate(
                make_spec_decode_step(cfg, max_len, k_max=self.top_k_max,
                                      spec_k=self.spec_k), (1, 2)))
        else:
            self._step = self._counted(maybe_donate(
                make_decode_sample_step(cfg, max_len, k_max=self.top_k_max),
                (1, 2)))
        # unified mixed prefill/decode step: one dispatch advances the whole
        # packed cursor frontier AND decodes every armed slot.  Not taken
        # for encoder-decoder / vision configs (their prefix embeddings ride
        # per-chunk) — those fall back to the per-chunk dispatch path.
        self.unified = (bool(unified_step) and self.chunk > 0
                        and not cfg.is_encdec and not cfg.num_vision_tokens)
        if self.unified:
            # static packed-frontier width: the budget bounds per-step chunk
            # work, and no cursor can hold more than max_len - 1 tokens
            self._chunk_width = min(self.chunk_budget, max(max_len - 1, 1))
            self._unified = self._counted(maybe_donate(
                make_engine_step(cfg, max_len, k_max=self.top_k_max,
                                 spec_k=self.spec_k), (1, 3)))
        # admission prefill: the n-row cache template is built *inside* the
        # jitted function (from the traced batch shape), so its zeros are
        # materialized on demand by XLA instead of living as per-batch-size
        # device-resident templates on the host
        self._prefill = self._counted(jax.jit(
            lambda p, batch: model_lib.prefill(
                cfg, p, batch, self._admit_template(batch))))
        self._prefill_paged = self._counted(jax.jit(
            lambda p, batch, live_cache, tables: model_lib.prefill(
                cfg, p, batch,
                self._graft_pools(self._admit_template(batch), live_cache),
                block_tables=tables)))

        # chunked prefill: one chunk of one slot against the live cache.
        # ``start`` and ``slot`` ride as traced scalars, so the executable
        # is compiled once per chunk *width* and replayed for every offset
        # and slot.  The slot's row is sliced out, the chunk is applied
        # (appending K/V mid-prompt), and the row is scattered back; pool
        # leaves pass through whole — the append already wrote into them
        # through the block table.
        def _chunk_body(p, batch, start, slots, cache, tables, lengths=None):
            part = self._slice_slots(cache, slots)
            logits, part = model_lib.prefill_chunk(
                cfg, p, batch, part, start, block_tables=tables,
                lengths=lengths)
            return logits, self._merge_admitted(cache, part, slots)

        self._chunk_contig = self._counted(maybe_donate(
            lambda p, batch, start, slots, cache, lengths=None: _chunk_body(
                p, batch, start, slots, cache, None, lengths), (4,)))
        self._chunk_paged = self._counted(maybe_donate(_chunk_body, (4,)))
        # admission-time reset of one slot's cache row to init values (the
        # unchunked path resets implicitly by overwriting the whole row at
        # prefill; a chunk only writes its own span, so stale positions /
        # recurrent state from the previous occupant must be cleared first)
        self._reset_rows = self._counted(maybe_donate(
            lambda cache, slots: self._merge_admitted(
                cache,
                self._graft_pools(
                    self._admit_template({"tokens": jnp.zeros(
                        (slots.shape[0], 1), jnp.int32)}), cache),
                slots), (0,)))

        # host-side token ring buffer: (max_batch, _RING) plus fill counts
        self._ring = np.zeros((max_batch, _RING), np.int32)
        self._ring_n = np.zeros(max_batch, np.int64)

        # energy attribution
        self.monitor = monitor
        self._win_t0: Optional[float] = None
        self._win_tokens: Dict[int, int] = {}
        self.attributed_joules = 0.0

        # token streaming: called from inside the per-step host sync with
        # (uid, new_tokens, finished) the moment tokens leave the device —
        # before the ring buffer defers them — so an HTTP front-end can
        # stream SSE chunks with per-step latency (serving/server.py)
        self.stream_hook: Optional[Callable[[int, List[int], bool], None]] = None

    def _counted(self, fn):
        """Wrap a jitted callable so every launch bumps ``_dispatches`` —
        and, on a tensor-parallel engine, runs under the engine's mesh so
        both tracing and replay see the sharding rules."""

        def run(*args):
            self._dispatches += 1
            if self._mesh is not None:
                with rules_lib.use_mesh(self._mesh, self._rules):
                    return fn(*args)
            return fn(*args)

        return run

    # -- public API -----------------------------------------------------------
    def submit(self, prompt: np.ndarray,
               params: Optional[SamplingParams] = None) -> int:
        params = params or SamplingParams()
        if params.top_k > self.top_k_max:
            params = dataclasses.replace(params, top_k=self.top_k_max)
        req = Request(uid=self._uid, prompt=np.asarray(prompt, np.int32),
                      params=params)
        req.submit_time = time.perf_counter()
        self._uid += 1
        self.queue.append(req)
        return req.uid

    @property
    def busy(self) -> bool:
        return (bool(self.queue) or bool(self._preempted)
                or any(s is not None for s in self.slots))

    def step(self) -> bool:
        """One admit + chunk + decode round; returns True if work was done.

        On the unified path the chunk advance and the decode are one fused
        dispatch (``make_engine_step``): the FCFS frontier is *picked* on
        the host first (no device work), block growth/preemption runs, and
        then a single launch advances every cursor row and decodes every
        armed slot.  The legacy path dispatches one chunk per cursor
        quantum plus a separate decode step."""
        if not self.busy:
            return False
        t0 = time.perf_counter()
        d0 = self._dispatches
        self._admit()
        self._flush_resets()  # one batched row-reset dispatch per step
        if self.unified:
            frontier = self._pick_frontier()
            if self.spec_k:
                self._arm_drafts()
            self._grow_decode_blocks()
            self._unified_once(frontier)
        else:
            self._advance_chunks()
            if self.spec_k:
                self._arm_drafts()
            self._grow_decode_blocks()
            self._decode_once()
        if self.layout == "paged":
            self._occ_samples.append(
                self._pool.in_use / max(self.num_blocks - 1, 1))
        if self._steps_t0 is None:
            self._steps_t0 = t0
        self._steps_t1 = time.perf_counter()
        self._steps_done += 1
        self._dispatch_samples.append(self._dispatches - d0)
        return True

    def run(self, max_steps: int = 10_000) -> List[Request]:
        """Drive until queue + slots drain (or step budget); returns finished."""
        steps = 0
        while self.busy and steps < max_steps:
            self.step()
            steps += 1
        self.flush()
        return self.finished

    def flush(self) -> None:
        """Drain host-side buffers: ring-buffered tokens of still-running
        requests (so ``output_tokens`` is complete even on a step-budget
        exit) and the open energy-attribution window."""
        for slot in range(self.max_batch):
            self._flush_ring(slot)
        self._flush_energy()

    def attach_monitor(self, monitor: PowerMonitor) -> None:
        """Start attributing the monitor's energy to requests from now on."""
        self.monitor = monitor
        self._win_t0 = None
        self._win_tokens = {}


    # -- internals --------------------------------------------------------------
    def _flush_resets(self) -> None:
        """Run the step's deferred admission row resets as one dispatch."""
        if self._pending_reset:
            slots, self._pending_reset = self._pending_reset, []
            self.cache = self._reset_rows(
                self.cache, jnp.asarray(slots, jnp.int32))

    def _bucketed(self, n: int) -> int:
        b = self.prompt_bucket
        return min(self.max_len - 1, ((n + b - 1) // b) * b)

    def _blocks_for(self, plen: int, max_new: int) -> int:
        """Pool blocks reserved at admission.

        With preemption off the full prompt + decode budget is reserved up
        front, so the fused step's append never has to allocate — but a
        pool smaller than the worst case then refuses load it could have
        served (most requests stop early).  Under ``preemption=
        "recompute"`` reservation is *lazy*: only the prompt plus the
        first decode write position, with later blocks grown on demand by
        ``_grow_decode_blocks`` (preempting a victim when the pool runs
        dry)."""
        budget = 1 if self.preemption != "off" else max_new
        tokens = min(plen + budget, self.max_len)
        return min(cache_lib.blocks_per_slot(tokens, self.block_size),
                   self.max_blocks_per_slot)

    @property
    def _free_blocks(self) -> List[int]:
        """The pool's LIFO free stack (read-only view for tests/metrics)."""
        return self._pool.free_stack

    @property
    def blocks_in_use(self) -> int:
        """Blocks owned by live requests.  Evictable cached blocks (kept
        only for future prefix hits, reclaimed on pressure) don't count."""
        if self.layout != "paged":
            return 0
        return self._pool.in_use

    # -- prefix cache ------------------------------------------------------------
    def _padded_prompt(self, req: Request, plen: int) -> np.ndarray:
        """The bucketed token row admission actually prefills (prompts
        longer than the bucket keep their newest context).

        ``pad_side="left"`` (default) zero-pads on the left, so the real
        tokens always end at the bucket boundary.  ``pad_side="right"``
        puts the content first: variable-length prompts sharing a prefix
        then hash to the *same* block chain regardless of their suffix
        length, so the prefix cache can share their blocks — at the cost
        of the row carrying a true span shorter than the bucket (pad
        positions past the span are never computed)."""
        use = req.prompt
        if len(use) > plen:
            use = use[-plen:]
            req.truncated = True
        toks = np.zeros(plen, np.int32)
        if self.pad_side == "left":
            toks[-len(use):] = use
        else:
            toks[:len(use)] = use
        return toks

    def _true_span(self, req: Request, plen: int) -> int:
        """Positions of the bucketed row that are actually computed: the
        whole row when left-padded, only the content prefix when
        right-padded."""
        if self.pad_side == "left":
            return plen
        return min(len(req.prompt), plen)

    def _lookup_width(self, span: int) -> int:
        """Cacheable-prefix cap: the block holding the last prompt position
        is always recomputed, so the final chunk's logits (which seed the
        first sampled token) exist even on a full-prefix hit."""
        return (span - 1) // self.block_size

    def _hashes_for(self, req: Request, plen: int) -> List[int]:
        """The request's full-block hash chain, memoized on the request —
        a backpressured queue head is re-probed every engine step."""
        if req._hash_cache is None or req._hash_cache[0] != plen:
            req._hash_cache = (plen, cache_lib.hash_token_blocks(
                self._padded_prompt(req, plen), self.block_size))
        return req._hash_cache[1]

    def _peek_hit(self, req: Request, plen: int) -> int:
        """Conservative admission-budget estimate of reusable blocks."""
        if not self.prefix_cache:
            return 0
        hashes = self._hashes_for(req, plen)
        span = self._true_span(req, plen)
        return self._pool.peek(hashes[:self._lookup_width(span)])

    def _admit(self) -> None:
        # preempted requests re-admit first, oldest admission first; a
        # parked head that does not fit blocks the waiting queue too —
        # new arrivals must not starve a request that already holds
        # emitted tokens (head-of-line progress guarantees drain)
        while self._preempted:
            if not self._try_readmit():
                return
        while self.queue:
            free = [s for s in range(self.max_batch) if self.slots[s] is None]
            if not free:
                return
            # the head of the queue defines the prompt bucket; batch every
            # queued request sharing it, in FCFS order, up to the free slots
            # and (paged) the free-stack budget.  A head that doesn't fit in
            # the pool blocks admission entirely — strict FCFS backpressure.
            plen = self._bucketed(len(self.queue[0].prompt))
            picked: List[Request] = []
            blocks_reserved = 0
            for req in self.queue:
                if len(picked) == len(free):
                    break
                if self._bucketed(len(req.prompt)) != plen:
                    continue
                if self.layout == "paged":
                    # prefix hits shrink the new-block need; _peek_hit is
                    # conservative (never counts a block an interleaved
                    # allocation could evict), so commit-time lookup can
                    # only find more hits than budgeted here, never fewer
                    span = self._true_span(req, plen)
                    nb = (self._blocks_for(span, req.params.max_new_tokens)
                          - self._peek_hit(req, plen))
                    if blocks_reserved + nb > self._pool.available:
                        break
                    blocks_reserved += nb
                picked.append(req)
            if not picked:
                return  # pool backpressure: wait for finishes to free blocks
            picked_ids = {id(r) for r in picked}
            self.queue = deque(
                r for r in self.queue if id(r) not in picked_ids)
            for req in picked:
                req.admit_seq = self._admit_seq
                self._admit_seq += 1
            slots_for = free[:len(picked)]
            if self.chunk > 0:
                self._admit_chunked(picked, slots_for, plen)
            elif self.pad_side == "right":
                # right-padded rows carry per-request true spans, which the
                # batched whole-row prefill can't express; admit through the
                # cursor path and run each span as one masked chunk
                self._admit_right_unchunked(picked, slots_for, plen)
            else:
                self._admit_batch(picked, slots_for, plen)

    def _admit_batch(self, reqs: List[Request], slots_for: List[int],
                     plen: int) -> None:
        """One batched prefill for ``reqs`` (all bucketed to ``plen``).

        With the prefix cache on, requests whose hashed prompt prefix is
        already resident are peeled off first and admitted through the
        suffix-only path (``_admit_prefix_hit``); the rest prefill cold in
        one batched call and register their full prompt blocks for future
        sharers.  Two same-prefix requests inside one cold batch register
        first-come-first-served — the loser's blocks simply stay private."""
        padded = [self._padded_prompt(r, plen) for r in reqs]
        hashes: List[Optional[List[int]]] = [None] * len(reqs)
        if self.prefix_cache:
            keep: List[int] = []
            for i, (req, slot) in enumerate(zip(reqs, slots_for)):
                hashes[i] = self._hashes_for(req, plen)
                hit = self._pool.lookup(hashes[i][:self._lookup_width(plen)])
                self.prefix_lookups += 1
                if hit:
                    self._admit_prefix_hit(req, slot, plen, padded[i],
                                           hashes[i], hit)
                else:
                    keep.append(i)
            if not keep:
                return
            reqs = [reqs[i] for i in keep]
            slots_for = [slots_for[i] for i in keep]
            padded = [padded[i] for i in keep]
            hashes = [hashes[i] for i in keep]
        n = len(reqs)
        batch = {"tokens": jnp.asarray(np.stack(padded))}
        if self.cfg.is_encdec:
            batch["enc_embeds"] = jnp.zeros(
                (n, max(plen // 2, 1), self.cfg.d_model), self._dtype)
        if self.cfg.num_vision_tokens:
            batch["vision_embeds"] = jnp.zeros(
                (n, self.cfg.num_vision_tokens, self.cfg.d_model), self._dtype)

        if self.layout == "paged":
            tables_np = np.zeros((n, self.max_blocks_per_slot), np.int32)
            for r, (req, slot) in enumerate(zip(reqs, slots_for)):
                nb = self._blocks_for(plen, req.params.max_new_tokens)
                blocks = self._pool.allocate(nb)
                tables_np[r, :nb] = blocks
                self._slot_blocks[slot] = blocks
                if self.prefix_cache:
                    # whole-prompt prefill lands below; the blocks are
                    # ready the moment any later admission could read them
                    for i in range(plen // self.block_size):
                        if self._pool.register(hashes[r][i], blocks[i]):
                            self._pool.mark_ready(blocks[i])
            self.peak_blocks_in_use = max(self.peak_blocks_in_use,
                                          self.blocks_in_use)
            tables = jnp.asarray(tables_np)
            logits, filled = self._prefill_paged(
                self.params, batch, self.cache, tables)
        else:
            logits, filled = self._prefill(self.params, batch)
        self.cache = self._merge_admitted(self.cache, filled, slots_for)

        for r, (req, slot) in enumerate(zip(reqs, slots_for)):
            self.slots[slot] = req
            self._start_decoding(
                req, slot, plen, logits[r:r + 1],
                tables_np[r] if self.layout == "paged" else None)

    def _claim_prefix_blocks(self, req: Request, slot: int, span: int,
                             hashes: List[int], hit: List[int],
                             nb: Optional[int] = None):
        """Commit one admission's pool blocks: reused prefix blocks first
        (already increfed by ``lookup``), freshly allocated ones after, in
        table order.  Full prompt blocks past the hit are registered for
        future sharers (not yet ready — the caller fills them).  ``span``
        is the computed extent of the row (== the bucket when left-padded;
        the content prefix when right-padded).  Returns ``(tables_np,
        start, pending)``: the slot's table row, the first position
        prefill must compute, and the (end, block) pairs to mark ready as
        the fill passes them.  ``nb`` overrides the block count (recompute
        re-admission covers prompt + generated tokens)."""
        h = len(hit)
        if nb is None:
            nb = self._blocks_for(span, req.params.max_new_tokens)
        blocks = hit + self._pool.allocate(nb - h)
        tables_np = np.zeros(self.max_blocks_per_slot, np.int32)
        tables_np[:nb] = blocks
        self._slot_blocks[slot] = blocks
        pending = []
        for i in range(h, span // self.block_size):
            if self._pool.register(hashes[i], blocks[i]):
                pending.append(((i + 1) * self.block_size, blocks[i]))
        if h:
            self.prefix_hits += 1
        self.prefix_blocks_reused += h
        start = h * self.block_size
        self.prefill_tokens_skipped += start
        self.peak_blocks_in_use = max(self.peak_blocks_in_use,
                                      self.blocks_in_use)
        return tables_np, start, pending

    def _admit_prefix_hit(self, req: Request, slot: int, plen: int,
                          toks: np.ndarray, hashes: List[int],
                          hit: List[int]) -> None:
        """Unchunked admission of a request with resident prefix blocks:
        only the suffix (first non-cached block onward) is prefilled, as a
        single chunk against the live pool — the reused blocks feed the
        suffix's attention through the block table, and the partial tail
        block is recomputed privately so decode writes never touch a
        shared block."""
        tables_np, start, pending = self._claim_prefix_blocks(
            req, slot, plen, hashes, hit)
        self.slots[slot] = req
        cur = _PrefillCursor(req=req, tokens=toks, plen=plen, next=start,
                             tables_np=tables_np)
        logits = self._run_chunk(slot, cur, plen - start)
        for _, blk in pending:  # suffix fully written: publish its blocks
            self._pool.mark_ready(blk)
        self._start_decoding(req, slot, plen, logits, tables_np)

    def _admit_chunked(self, reqs: List[Request], slots_for: List[int],
                       plen: int) -> None:
        """Admission with chunked prefill: reserve the slot (and pool
        blocks) and set up a chunk cursor; no prompt work happens yet, so
        admission never stalls in-flight decodes.  The slot's cache row is
        reset to init values — chunk writes only cover the prompt span,
        and stale positions / recurrent state from the previous occupant
        would otherwise leak into the chunk's attention and state."""
        for req, slot in zip(reqs, slots_for):
            toks = self._padded_prompt(req, plen)
            span = self._true_span(req, plen)
            tables_np = None
            start = 0
            pending: List = []
            if self.layout == "paged" and self.prefix_cache:
                # reuse resident prefix blocks: the cursor starts at the
                # first non-cached block and its chunks attend to the
                # shared blocks through the block table
                hashes = self._hashes_for(req, plen)
                hit = self._pool.lookup(hashes[:self._lookup_width(span)])
                self.prefix_lookups += 1
                tables_np, start, pending = self._claim_prefix_blocks(
                    req, slot, span, hashes, hit)
            elif self.layout == "paged":
                nb = self._blocks_for(span, req.params.max_new_tokens)
                blocks = self._pool.allocate(nb)
                tables_np = np.zeros(self.max_blocks_per_slot, np.int32)
                tables_np[:nb] = blocks
                self._slot_blocks[slot] = blocks
            self.slots[slot] = req
            self._cursors[slot] = _PrefillCursor(
                req=req, tokens=toks, plen=span, next=start,
                tables_np=tables_np, pending_ready=pending)
            self._prefill_order.append(slot)
            if tables_np is not None:
                # arm the table row now: the unified step's packed chunk
                # routes through state["block_tables"] (inert on the
                # per-chunk path — tables ride as an explicit argument)
                self._state["block_tables"] = (
                    self._state["block_tables"].at[slot].set(
                        jnp.asarray(tables_np)))
        if self.layout == "paged":
            self.peak_blocks_in_use = max(self.peak_blocks_in_use,
                                          self.blocks_in_use)
        # defer the row resets: every admission of the step lands in ONE
        # batched _reset_rows dispatch (flushed before any chunk runs),
        # keeping the unified path at <= 2 dispatches per engine step
        self._pending_reset.extend(slots_for)

    def _admit_right_unchunked(self, reqs: List[Request],
                               slots_for: List[int], plen: int) -> None:
        """Unchunked admission of right-padded rows: reserve through the
        chunked path (which already handles per-request true spans and
        prefix hits), then immediately run each request's whole span as
        one masked chunk padded to the bucket width — so the request is
        decode-eligible in the same step, matching the left-padded
        unchunked admission's semantics, while every bucket still
        compiles a single chunk executable."""
        self._admit_chunked(reqs, slots_for, plen)
        self._flush_resets()  # the spans run now, not at the step's flush
        for slot in list(slots_for):
            cur = self._cursors[slot]
            if cur is None:
                continue
            c = cur.plen - cur.next
            logits = self._run_chunk(slot, cur, c, pad_to=plen)
            cur.next = cur.plen
            while cur.pending_ready:
                self._pool.mark_ready(cur.pending_ready.pop(0)[1])
            self._prefill_order.remove(slot)
            self._cursors[slot] = None
            self._start_decoding(cur.req, slot, cur.plen, logits,
                                 cur.tables_np)

    def _advance_chunks(self) -> None:
        """Spend the per-step prefill budget on cursors, FCFS.  A cursor's
        next chunk runs only if it fits the remaining budget, bounding the
        prompt work any single engine step (and therefore any in-flight
        decode token) waits on."""
        budget = self.chunk_budget
        while budget > 0 and self._prefill_order:
            slot = self._prefill_order[0]
            cur = self._cursors[slot]
            c = min(self.chunk, cur.plen - cur.next)
            if c > budget:
                return
            budget -= c
            logits = self._run_chunk(slot, cur, c)
            cur.next += c
            # publish registered blocks the cursor has fully written, so
            # later admissions can share this still-prefilling prompt
            while cur.pending_ready and cur.pending_ready[0][0] <= cur.next:
                self._pool.mark_ready(cur.pending_ready.pop(0)[1])
            if cur.next == cur.plen:  # final chunk landed: decode-eligible
                self._prefill_order.pop(0)
                self._cursors[slot] = None
                if cur.resume_n > 0:
                    self._resume_decoding(cur.req, slot, cur.plen,
                                          cur.resume_n, cur.tables_np)
                else:
                    self._start_decoding(cur.req, slot, cur.plen, logits,
                                         cur.tables_np)

    def _run_chunk(self, slot: int, cur: _PrefillCursor, c: int,
                   pad_to: int = 0):
        """One chunk of one slot's prompt through the jitted chunk step.

        ``pad_to > c`` zero-pads the token row to a static width and
        threads the true length through the masked-append path (used by
        right-padded unchunked admission, so every bucket width compiles
        one executable regardless of each prompt's true span)."""
        toks = cur.tokens[cur.next:cur.next + c]
        lengths = None
        if pad_to > c:
            toks = np.concatenate([toks, np.zeros(pad_to - c, np.int32)])
            lengths = jnp.asarray([c], jnp.int32)
        batch = {"tokens": jnp.asarray(toks[None])}
        start = cur.next
        nv = self.cfg.num_vision_tokens
        if self.cfg.is_encdec:
            batch["enc_embeds"] = jnp.zeros(
                (1, max(cur.plen // 2, 1), self.cfg.d_model), self._dtype)
        if nv:
            # the VLM patch prefix rides with chunk 0; later chunks shift
            # past it — mirroring the unchunked prefill's concatenation
            if start == 0:
                batch["vision_embeds"] = jnp.zeros(
                    (1, nv, self.cfg.d_model), self._dtype)
            else:
                start += nv
        slots = jnp.asarray([slot], jnp.int32)
        if self.layout == "paged":
            logits, self.cache = self._chunk_paged(
                self.params, batch, start, slots, self.cache,
                jnp.asarray(cur.tables_np[None]), lengths)
        else:
            logits, self.cache = self._chunk_contig(
                self.params, batch, start, slots, self.cache, lengths)
        return logits

    # -- unified mixed prefill/decode step ---------------------------------------
    def _pick_frontier(self) -> List[tuple]:
        """The FCFS cursor frontier one unified step will advance: exactly
        the chunks ``_advance_chunks`` would run, but *picked* instead of
        dispatched, with consecutive quanta of the same head cursor
        coalesced into one packed row (their positions are consecutive, so
        one masked row of width <= budget covers them).  Returns
        ``[(slot, cursor, n_tokens)]``; budget semantics are identical to
        the legacy loop — a head chunk that doesn't fit the remaining
        budget stops the scan."""
        budget = self.chunk_budget
        frontier: List[tuple] = []
        for slot in self._prefill_order:
            cur = self._cursors[slot]
            take = 0
            while True:
                c = min(self.chunk, cur.plen - cur.next - take)
                if c <= 0 or c > budget:
                    break
                take += c
                budget -= c
            if take:
                frontier.append((slot, cur, take))
            if cur.next + take < cur.plen:
                break  # head cursor unfinished: no budget flows past it
        return frontier

    def _unified_once(self, frontier: List[tuple]) -> None:
        """One fused device dispatch: advance the packed frontier and run
        decode+sample+finish for every armed slot.  With no frontier this
        degrades to the plain decode step (still one dispatch)."""
        if not frontier:
            self._decode_once()
            return
        W = self._chunk_width
        tokens = np.zeros((self.max_batch, W), np.int32)
        starts = np.zeros(self.max_batch, np.int32)
        lens = np.zeros(self.max_batch, np.int32)
        for slot, cur, c in frontier:
            tokens[slot, :c] = cur.tokens[cur.next:cur.next + c]
            starts[slot] = cur.next
            lens[slot] = c
        chunk = {"tokens": jnp.asarray(tokens), "start": jnp.asarray(starts),
                 "length": jnp.asarray(lens)}
        self._state, self.cache, out, chunk_logits = self._unified(
            self.params, self._state, chunk, self.cache)
        # the single packed host<->device sync of the step
        out_np, logits_np = jax.device_get((out, chunk_logits))
        for slot, cur, c in frontier:
            if self._cursors[slot] is not cur:
                # the slot was preempted between frontier pick and dispatch
                # (_grow_decode_blocks ran dry): its table row was pointed
                # at the garbage block before the launch, so the chunk's
                # writes landed in trash — drop the stale advance
                continue
            cur.next += c
            while cur.pending_ready and cur.pending_ready[0][0] <= cur.next:
                self._pool.mark_ready(cur.pending_ready.pop(0)[1])
            if cur.next == cur.plen:  # final chunk landed: decode-eligible
                self._prefill_order.remove(slot)
                self._cursors[slot] = None
                if cur.resume_n > 0:
                    self._resume_decoding(cur.req, slot, cur.plen,
                                          cur.resume_n, cur.tables_np)
                else:
                    self._start_decoding(cur.req, slot, cur.plen,
                                         logits_np[slot:slot + 1],
                                         cur.tables_np)
        self._process_out(out_np)

    # -- speculative decoding ----------------------------------------------------
    def _arm_drafts(self) -> None:
        """Upload each decoding slot's prompt-lookup draft for this step's
        verify dispatch.  Drafting is pure host work over tokens the
        request already owns (prompt + emitted, including the unflushed
        ring tail), so it costs no device dispatch.  The draft length is
        clamped so the verify window — which writes K/V at the last
        emitted token's pending position plus one per draft token, and may
        emit up to ``draft_len + 1`` tokens — can never outrun the
        request's new-token budget or the cache length bound.  Both
        arrays are rebuilt from zero every step, so a slot that was
        re-armed, preempted, or finished can never replay a stale draft."""
        K = self.spec_k
        draft_np = np.zeros((self.max_batch, K), np.int32)
        self._draft_len_host[:] = 0
        for slot in range(self.max_batch):
            req = self.slots[slot]
            if req is None or self._cursors[slot] is not None:
                continue
            n_ring = int(self._ring_n[slot])
            emitted = len(req.output_tokens) + n_ring
            p = int(self._next_pos[slot])
            cap = min(K, req.params.max_new_tokens - emitted - 1,
                      self.max_len - 2 - p)
            if cap <= 0:
                continue
            hist = ([int(t) for t in req.prompt] + req.output_tokens
                    + [int(t) for t in self._ring[slot, :n_ring]])
            d = prompt_lookup_draft(hist, cap)
            if not d:
                continue
            draft_np[slot, :len(d)] = d
            self._draft_len_host[slot] = len(d)
            self._drafted_tokens += len(d)
        self._state["draft"] = jnp.asarray(draft_np)
        self._state["draft_len"] = jnp.asarray(
            self._draft_len_host.astype(np.int32))

    def _process_spec_out(self, out_np: np.ndarray) -> None:
        """Host-side bookkeeping of one verify's packed (B, 2*(k+1)+1)
        output: per slot, the emission mask is a prefix of the window
        (the acceptance chain only ever shuts off), so the first ``n``
        token columns are the slot's emitted tokens in stream order."""
        K1 = self.spec_k + 1
        tokens = out_np[:, :K1]
        emit = out_np[:, K1:2 * K1]
        done = out_np[:, 2 * K1]
        any_emit = False
        for slot in range(self.max_batch):
            req = self.slots[slot]
            n = int(emit[slot].sum())
            if req is None or n == 0:
                continue  # idle slot, or freed on the host side
            any_emit = True
            self._spec_verifies += 1
            self._accepted_tokens += n - 1
            self._decode_tokens += n
            for i in range(n):
                self._next_pos[slot] += 1  # the device wrote K/V there
                rn = int(self._ring_n[slot])
                self._ring[slot, rn] = tokens[slot, i]
                self._ring_n[slot] = rn + 1
                if rn + 1 == _RING:
                    self._flush_ring(slot)
                self._count_token(req)
            self._notify_stream(req, [int(t) for t in tokens[slot, :n]])
            if done[slot]:
                self._finish(slot)
            elif self.preemption != "off":
                self._rollback_spec_blocks(slot)
        if any_emit:
            self._decode_dispatches += 1

    def _rollback_spec_blocks(self, slot: int) -> None:
        """Free the lazily grown blocks a rejected draft suffix no longer
        needs (``preemption="recompute"`` only — with up-front reservation
        the window never grew past the admission grant).  The rejected
        K/V itself is never rolled back: entries within the next window's
        span are overwritten before they are read, entries beyond it sit
        at positions above every query and are causally masked, and a
        freed block handed to another request exposes only positions that
        request has not reached yet."""
        keep = int(self._next_pos[slot]) // self.block_size + 1
        blocks = self._slot_blocks[slot]
        if len(blocks) <= keep:
            return
        extra = blocks[keep:]
        self._slot_blocks[slot] = blocks[:keep]
        self._pool.free(extra)
        self._state["block_tables"] = (
            self._state["block_tables"].at[slot, keep:keep + len(extra)].set(
                cache_lib.GARBAGE_BLOCK))

    # -- preemption + recompute ------------------------------------------------
    def _grow_decode_blocks(self) -> None:
        """Lazy block growth (``preemption="recompute"`` only): before the
        fused step runs, every decoding slot whose next write position
        crosses into an unallocated block gets one.  When the pool is dry
        (free stack and evictable LRU both empty) the newest-admitted
        in-flight request is preempted — possibly the growing slot itself
        — and its reclaimed blocks satisfy the growth.  The head-of-line
        (oldest ``admit_seq``) is never a victim, so it always progresses
        and the engine is guaranteed to drain."""
        if self.layout != "paged" or self.preemption == "off":
            return
        bs = self.block_size
        for slot in range(self.max_batch):
            req = self.slots[slot]
            if req is None or self._cursors[slot] is not None:
                continue
            # the verify window writes draft_len positions past next_pos,
            # so speculative growth must cover the whole window up front
            need = (int(self._next_pos[slot])
                    + int(self._draft_len_host[slot])) // bs + 1
            while len(self._slot_blocks[slot]) < need:
                if self.slots[slot] is not req:
                    break  # the growing slot itself was preempted
                if self._pool.available == 0:
                    victim = self._pick_victim()
                    assert victim is not None, (
                        "pool dry with no preemptible victim — the pool "
                        "is smaller than one worst-case request")
                    self._preempt(victim)
                    continue
                blk = self._pool.allocate(1)[0]
                self._slot_blocks[slot].append(blk)
                idx = len(self._slot_blocks[slot]) - 1
                self._state["block_tables"] = (
                    self._state["block_tables"].at[slot, idx].set(blk))
            self.peak_blocks_in_use = max(self.peak_blocks_in_use,
                                          self.blocks_in_use)

    def _pick_victim(self) -> Optional[int]:
        """LIFO victim selection: the newest-admitted in-flight request,
        never the head-of-line (the oldest)."""
        live = [s for s in range(self.max_batch) if self.slots[s] is not None]
        if len(live) < 2:
            return None
        head = min(live, key=lambda s: self.slots[s].admit_seq)
        return max((s for s in live if s != head),
                   key=lambda s: self.slots[s].admit_seq)

    def _preempt(self, slot: int) -> None:
        """Park one in-flight request: flush its emitted tokens, reclaim
        its blocks (shared prefix blocks only decref — a block with other
        live readers is never reclaimed), mask the device row, and queue
        it for recompute re-admission."""
        req = self.slots[slot]
        assert req is not None
        assert any(r is not None and r.admit_seq < req.admit_seq
                   for r in self.slots), "head-of-line request preempted"
        self._flush_ring(slot)
        if self._cursors[slot] is not None:  # parked mid-prefill
            self._cursors[slot] = None
            self._prefill_order.remove(slot)
        self.slots[slot] = None
        self._state = invalidate_slot(self._state, slot,
                                      garbage_block=cache_lib.GARBAGE_BLOCK)
        if self._slot_blocks[slot]:
            self._pool.free(self._slot_blocks[slot])
            self._slot_blocks[slot] = []
        self.preemptions += 1
        req.preemptions += 1
        self._preempted.append(req)
        self._preempted.sort(key=lambda r: r.admit_seq)

    def _try_readmit(self) -> bool:
        """Re-admit the oldest parked request if a slot and enough blocks
        are available: its prompt plus every token generated before the
        preemption are recomputed through the chunked-prefill path (one
        chunk in unchunked mode), then the slot is re-armed exactly where
        it left off.  Resident shared-prefix blocks are reused like any
        admission, so a preempted sharer recomputes only its private
        suffix."""
        req = self._preempted[0]
        free = [s for s in range(self.max_batch) if self.slots[s] is None]
        if not free:
            return False
        plen = self._bucketed(len(req.prompt))
        span = self._true_span(req, plen)
        n = len(req.output_tokens)
        total = span + max(n - 1, 0)  # positions to recompute: 0..total-1
        nb = min(cache_lib.blocks_per_slot(min(total + 1, self.max_len),
                                           self.block_size),
                 self.max_blocks_per_slot)
        if nb - self._peek_hit(req, plen) > self._pool.available:
            return False
        self._preempted.pop(0)
        slot = free[0]
        toks = self._padded_prompt(req, plen)[:span]
        if n > 1:
            toks = np.concatenate(
                [toks, np.asarray(req.output_tokens[:n - 1], np.int32)])
        start = 0
        pending: List = []
        if self.prefix_cache:
            hashes = self._hashes_for(req, plen)
            hit = self._pool.lookup(hashes[:self._lookup_width(span)])
            self.prefix_lookups += 1
            tables_np, start, pending = self._claim_prefix_blocks(
                req, slot, span, hashes, hit, nb=nb)
        else:
            blocks = self._pool.allocate(nb)
            tables_np = np.zeros(self.max_blocks_per_slot, np.int32)
            tables_np[:nb] = blocks
            self._slot_blocks[slot] = blocks
            self.peak_blocks_in_use = max(self.peak_blocks_in_use,
                                          self.blocks_in_use)
        self.slots[slot] = req
        self.recompute_tokens += total - start
        # the slot row may have held another request since: stale positions
        # / recurrent state must be cleared before the replay scatters into
        # it (deferred into the step's single batched reset dispatch)
        self._pending_reset.append(slot)
        cur = _PrefillCursor(req=req, tokens=toks, plen=total, next=start,
                             tables_np=tables_np, pending_ready=pending,
                             resume_n=n)
        if self.chunk > 0:
            self._cursors[slot] = cur
            self._prefill_order.append(slot)
            # arm the table row for the unified step's packed chunk (the
            # per-chunk path passes tables explicitly; harmless there)
            self._state["block_tables"] = (
                self._state["block_tables"].at[slot].set(
                    jnp.asarray(tables_np)))
        else:
            self._flush_resets()  # the replay chunk runs right now
            logits = self._run_chunk(slot, cur, total - start)
            for _, blk in pending:
                self._pool.mark_ready(blk)
            if n > 0:
                self._resume_decoding(req, slot, total, n, tables_np)
            else:
                self._start_decoding(req, slot, total, logits, tables_np)
        return True

    def _resume_decoding(self, req: Request, slot: int, position: int,
                         n: int, tables_np: Optional[np.ndarray]) -> None:
        """Re-arm a recomputed slot exactly where the preemption cut it
        off.  The next input token is the last one emitted before parking
        (its K/V lands on the next fused step, like any decode write), so
        no logits are consumed and nothing is re-sampled.  The per-slot
        PRNG chain is restored to the same point — the chain seed split
        once per device-emitted token (``n - 1`` of them: the first token
        came from the host-side admission draw) — so the resumed stream
        is byte-identical to an unpreempted run."""
        rk = jax.random.fold_in(self._base_key, req.uid)
        key = self._advance_chain(jax.random.fold_in(rk, 1), n - 1)
        remaining = req.params.max_new_tokens - n
        # a live preempted request always has budget and headroom left
        # (finish flags are processed before preemption can run); guard
        # anyway so a corrupt resume finishes instead of decoding forever
        active = remaining > 0 and position < self.max_len - 1
        self._write_slot_state(
            slot, token=req.output_tokens[-1], position=position,
            remaining=remaining, params=req.params, active=active, key=key)
        if tables_np is not None:
            self._state["block_tables"] = (
                self._state["block_tables"].at[slot].set(
                    jnp.asarray(tables_np)))
        self._next_pos[slot] = position
        if not active:
            self._finish(slot)

    def _start_decoding(self, req: Request, slot: int, plen: int,
                        logits, tables_np: Optional[np.ndarray]) -> None:
        """Transition a slot to the decoding state: sample the first token
        from the prefill's last-position logits and arm the device row.
        Shared by unchunked admission and final-chunk completion."""
        rk = jax.random.fold_in(self._base_key, req.uid)
        first = int(sample(logits, req.params, jax.random.fold_in(rk, 0))[0])
        req.first_token_time = time.perf_counter()
        req.output_tokens.append(first)
        self._count_token(req)
        self._notify_stream(req, [first])

        done = (req.params.max_new_tokens <= 1
                or (req.params.eos_token >= 0
                    and first == req.params.eos_token)
                or plen >= self.max_len - 1)
        self._write_slot_state(
            slot, token=first, position=plen,
            remaining=req.params.max_new_tokens - 1,
            params=req.params, active=not done,
            key=jax.random.fold_in(rk, 1))
        self._next_pos[slot] = plen
        if self.layout == "paged" and tables_np is not None:
            self._state["block_tables"] = (
                self._state["block_tables"].at[slot].set(
                    jnp.asarray(tables_np)))
        if done:
            self._finish(slot)

    def _write_slot_state(self, slot: int, *, token: int, position: int,
                          remaining: int, params: SamplingParams,
                          active: bool, key) -> None:
        """Admission-time write of one slot's device state (lazy device ops)."""
        s = self._state
        s["tokens"] = s["tokens"].at[slot, 0].set(token)
        s["positions"] = s["positions"].at[slot].set(position)
        s["remaining"] = s["remaining"].at[slot].set(remaining)
        s["temperature"] = s["temperature"].at[slot].set(params.temperature)
        s["top_k"] = s["top_k"].at[slot].set(params.top_k)
        s["eos"] = s["eos"].at[slot].set(params.eos_token)
        s["active"] = s["active"].at[slot].set(active)
        s["keys"] = s["keys"].at[slot].set(key)

    def _admit_template(self, batch: Dict) -> Dict:
        """Fresh prefill cache for an admitted batch (traced under jit)."""
        n = batch["tokens"].shape[0]
        return model_lib.init_cache(
            self.cfg, n, self.max_len, self._dtype, layout=self.layout,
            block_size=self.block_size,
            # dummy 1-block pools; the live pools are grafted in per admit
            num_blocks=1 if self.layout == "paged" else 0)

    @staticmethod
    def _graft_pools(tmpl: Dict, live_cache: Dict) -> Dict:
        """Swap the template's dummy pools for the live shared pools."""

        def pick(path, t, live):
            return live if path[-1].key in ("kp", "vp") else t

        return jax.tree_util.tree_map_with_path(pick, tmpl, live_cache)

    def _slice_slots(self, cache, slots):
        """Gather ``slots`` rows of the live cache into an n-row cache.

        Mirror image of ``_merge_admitted``: pool leaves (``kp``/``vp``)
        are shared across slots and pass through whole; per-slot leaves
        take the batch-axis gather (axis 1 under ``groups``, 0 under
        ``rest``); scalar bookkeeping passes through."""

        def pick(path, leaf):
            if path[-1].key in ("kp", "vp"):
                return leaf
            axis = 1 if path[0].key == "groups" else 0
            if leaf.ndim <= axis:
                return leaf
            return jnp.take(leaf, slots, axis=axis)

        return jax.tree_util.tree_map_with_path(pick, cache)

    def _merge_admitted(self, full_cache, part_cache, slots_for: List[int]):
        """Write a freshly prefilled ``len(slots_for)``-row cache into the
        decode cache: row ``r`` lands in slot ``slots_for[r]``.

        Pool leaves (``kp``/``vp``) already *are* the updated shared pools
        (prefill scattered into them through the block tables) and pass
        through; per-slot leaves land in one scatter per leaf (not one
        copy per admitted row).  Leaves under ``groups`` carry a leading
        scan-group axis, so the batch dim is axis 1 there and axis 0
        under ``rest``.
        """
        slots = jnp.asarray(slots_for, jnp.int32)

        def merge(path, full, part):
            if path[-1].key in ("kp", "vp"):
                return part
            axis = 1 if path[0].key == "groups" else 0
            if full.ndim <= axis:
                return full  # scalars / shared bookkeeping (e.g. `ring`)
            part = part.astype(full.dtype)
            if axis == 0:
                return full.at[slots].set(part)
            return full.at[:, slots].set(part)

        return jax.tree_util.tree_map_with_path(merge, full_cache, part_cache)

    def _decode_once(self) -> None:
        # prefilling slots (open cursor) are not decode-eligible: their
        # first token is sampled only once the final chunk lands
        if not any(req is not None and cur is None
                   for req, cur in zip(self.slots, self._cursors)):
            return
        self._state, self.cache, out = self._step(
            self.params, self._state, self.cache)
        self._process_out(np.asarray(out))  # single host sync

    def _process_out(self, out_np: np.ndarray) -> None:
        """Route one step's packed device sync to the right parser: the
        (3, B) decode sync or the (B, 2*(k+1)+1) speculative verify sync."""
        if self.spec_k:
            self._process_spec_out(out_np)
        else:
            self._process_decode_out(out_np)

    def _process_decode_out(self, out_np: np.ndarray) -> None:
        """Host-side bookkeeping of one decode's packed (3, B) output
        (shared by the split and unified step paths)."""
        tokens, done, emitted = out_np[0], out_np[1], out_np[2]
        any_emit = False
        for slot in np.nonzero(emitted)[0]:
            req = self.slots[slot]
            if req is None:
                continue  # stale flag for a slot freed on the host side
            any_emit = True
            self._decode_tokens += 1
            self._next_pos[slot] += 1  # the device wrote K/V there
            n = int(self._ring_n[slot])
            self._ring[slot, n] = tokens[slot]
            self._ring_n[slot] = n + 1
            if n + 1 == _RING:
                self._flush_ring(slot)
            self._count_token(req)
            self._notify_stream(req, [int(tokens[slot])])
            if done[slot]:
                self._finish(slot)
        if any_emit:
            self._decode_dispatches += 1

    def _notify_stream(self, req: Request, tokens: List[int],
                       finished: bool = False) -> None:
        """Push freshly emitted tokens (and the finish edge) to the
        streaming hook.  Called at emission time — recompute re-admission
        replays tokens through the *prefill* path, so a preempted request
        never re-notifies tokens it already streamed."""
        if self.stream_hook is not None:
            self.stream_hook(req.uid, tokens, finished)

    def _flush_ring(self, slot: int) -> None:
        n = int(self._ring_n[slot])
        req = self.slots[slot]
        if req is not None and n:
            req.output_tokens.extend(int(t) for t in self._ring[slot, :n])
        self._ring_n[slot] = 0

    def _finish(self, slot: int) -> None:
        req = self.slots[slot]
        if req is None:
            return
        if self._cursors[slot] is not None:  # abandoned mid-prefill
            self._cursors[slot] = None
            self._prefill_order.remove(slot)
        self._flush_ring(slot)
        req.finish_time = time.perf_counter()
        self.finished.append(req)
        self.slots[slot] = None
        # mask the device row (active already cleared by the fused step for
        # decode finishes; admission-time finishes need it explicitly) and
        # point the paged table row at the garbage block so idle writes
        # land in trash
        self._state = invalidate_slot(self._state, slot,
                                      garbage_block=cache_lib.GARBAGE_BLOCK)
        if self.layout == "paged" and self._slot_blocks[slot]:
            # return the slot's blocks: shared blocks decref and park on
            # the evictable LRU; private ones hit the free stack
            self._pool.free(self._slot_blocks[slot])
            self._slot_blocks[slot] = []
        self._flush_energy()
        # after _flush_energy: the finish notification carries the
        # request's final joules share with it
        self._notify_stream(req, [], finished=True)

    # -- memory accounting -------------------------------------------------------
    def kv_bytes_in_use(self, peak: bool = False) -> int:
        """Full-context attention KV bytes the engine actually holds.

        Paged: blocks in use (or the high-water mark with ``peak=True``)
        times per-block bytes across the paged layers.  Contiguous: the
        worst-case ``(max_batch, max_len)`` stripes — allocated up front
        regardless of load, which is exactly what paging removes.
        """
        if self.layout == "paged":
            blocks = self.peak_blocks_in_use if peak else self.blocks_in_use
            return self._n_attn_layers * blocks * self.block_size * self._kv_tok_bytes
        return self.kv_bytes_worst_case

    @property
    def kv_bytes_worst_case(self) -> int:
        """Contiguous-layout footprint: every slot at ``max_len``."""
        return self._n_attn_layers * self.max_batch * self.max_len * self._kv_tok_bytes

    @property
    def _n_attn_layers(self) -> int:
        return sum(1 for kind in self.cfg.blocks() if kind == "attn")

    @property
    def _kv_tok_bytes(self) -> int:
        cfg = self.cfg
        return 2 * cfg.num_kv_heads * cfg.resolved_head_dim * self._dtype.itemsize

    @property
    def n_devices(self) -> int:
        """Mesh devices the engine shards over (1 without a mesh)."""
        if self._mesh is None:
            return 1
        return int(np.prod(list(self._mesh.shape.values())))

    def kv_bytes_by_device(self, peak: bool = False) -> List[int]:
        """Physically resident attention-KV bytes per mesh device.

        Computed from the live cache leaves' actual shard shapes, so it
        reports what each device truly holds: when the KV heads dim shards
        over ``tp`` the per-device values sum exactly to
        ``kv_bytes_in_use``; a leaf whose heads don't divide the axis is
        replicated, and then every device carries its full copy (the sum
        exceeds the logical aggregate by design — replication is real
        memory).  Scope matches the aggregate: paged pool leaves
        (``kp``/``vp``) scaled by blocks in use (or the high-water mark
        with ``peak=True``); contiguous ``k``/``v`` stripes whole.
        """
        if self._mesh is None:
            return [self.kv_bytes_in_use(peak)]
        devices = list(self._mesh.devices.flat)
        per = {d.id: 0 for d in devices}
        blocks = self.peak_blocks_in_use if peak else self.blocks_in_use

        def visit(path, leaf):
            name = str(getattr(path[-1], "key",
                               getattr(path[-1], "idx", path[-1])))
            itemsize = jnp.dtype(leaf.dtype).itemsize
            if self.layout == "paged":
                if name not in ("kp", "vp"):
                    return
                for sh in leaf.addressable_shards:
                    if sh.device.id in per:
                        # the block axis never shards: each device holds
                        # size/num_blocks elements per block of this leaf
                        per[sh.device.id] += (
                            sh.data.size // self.num_blocks) * blocks * itemsize
            else:
                if name not in ("k", "v"):
                    return
                for sh in leaf.addressable_shards:
                    if sh.device.id in per:
                        per[sh.device.id] += sh.data.size * itemsize

        jax.tree_util.tree_map_with_path(visit, self.cache)
        return [per[d.id] for d in devices]

    def pool_accounting_by_device(self) -> List[Dict[str, int]]:
        """Per-device block accounting (see ``BlockPool.shard_accounting``):
        block tables are host-managed and shared, so each device's pool
        holds the same free/in-use/evictable partition of its KV shard."""
        return self._pool.shard_accounting(self.n_devices)

    # -- energy attribution ------------------------------------------------------
    def _count_token(self, req: Request) -> None:
        if self.monitor is None:
            return
        if self._win_t0 is None:
            t0 = self.monitor.window[0]
            self._win_t0 = t0 if t0 > 0.0 else time.perf_counter()
        self._win_tokens[req.uid] = self._win_tokens.get(req.uid, 0) + 1

    def _flush_energy(self) -> None:
        """Close the current window: split its joules by token counts."""
        if self.monitor is None or self._win_t0 is None:
            return
        t1 = time.perf_counter()
        joules = self.monitor.joules_between(self._win_t0, t1)
        total = sum(self._win_tokens.values())
        if total > 0 and joules > 0.0:
            by_uid = {r.uid: r for r in self.finished}
            for s in list(self.slots) + self._preempted:
                if s is not None:
                    by_uid[s.uid] = s
            for uid, n in self._win_tokens.items():
                share = joules * n / total
                if uid in by_uid:
                    by_uid[uid].joules += share
                self.attributed_joules += share
        self._win_t0 = t1
        self._win_tokens = {}

    # -- metrics -----------------------------------------------------------------
    def latency_summary(self) -> Dict[str, float]:
        if not self.finished:
            return {}
        ttfts = [r.ttft_s for r in self.finished]
        tpots = [r.tpot_s for r in self.finished]
        ttlts = [r.ttlt_s for r in self.finished]
        mean = lambda xs: sum(xs) / len(xs)
        out_tokens = sum(len(r.output_tokens) for r in self.finished)
        t_first = min(r.submit_time for r in self.finished)
        t_last = max(r.finish_time for r in self.finished)
        span = max(t_last - t_first, 1e-9)
        # decode vs prefill throughput: emitted tokens and processed prompt
        # tokens over the same request span (prompts are clipped to the
        # computed extent, matching what the prefill path actually ran)
        prefill_tokens = sum(min(len(r.prompt), self.max_len - 1)
                             for r in self.finished)
        summary = {
            "requests": len(self.finished),
            "truncated": sum(1 for r in self.finished if r.truncated),
            "output_tokens": out_tokens,
            "tokens_per_sec": out_tokens / span,
            "decode_tokens_per_sec": out_tokens / span,
            "prefill_tokens_per_sec": prefill_tokens / span,
            "tokens_per_dispatch": (
                self._decode_tokens / max(self._decode_dispatches, 1)),
            "ttft_ms": mean(ttfts) * 1e3,
            "tpot_ms": mean(tpots) * 1e3,
            "ttlt_ms": mean(ttlts) * 1e3,
        }
        for name, xs in (("ttft", ttfts), ("tpot", tpots), ("ttlt", ttlts)):
            for q in (50, 95, 99):
                summary[f"{name}_p{q}_ms"] = _percentile(xs, q) * 1e3
        summary["kv_bytes_peak"] = self.kv_bytes_in_use(peak=True)
        summary["kv_bytes_worst_case"] = self.kv_bytes_worst_case
        if self._mesh is not None:
            summary["tp_devices"] = self.n_devices
            summary["kv_bytes_peak_per_device"] = self.kv_bytes_by_device(
                peak=True)
            if self.layout == "paged":
                summary["pool_blocks_in_use_per_device"] = [
                    v["in_use"] for v in self.pool_accounting_by_device()]
        if self._steps_done:
            wall = max(self._steps_t1 - (self._steps_t0 or 0.0), 1e-9)
            summary["steps_per_sec"] = self._steps_done / wall
            summary["dispatches_per_step_p50"] = _percentile(
                self._dispatch_samples, 50)
            summary["dispatches_per_step_p95"] = _percentile(
                self._dispatch_samples, 95)
        if self.spec_k:
            summary["drafted_tokens"] = self._drafted_tokens
            summary["accepted_tokens"] = self._accepted_tokens
            summary["spec_accept_rate"] = (
                self._accepted_tokens / max(self._drafted_tokens, 1))
        if self.layout == "paged":
            summary["preemptions"] = self.preemptions
            summary["recompute_tokens"] = self.recompute_tokens
            summary["pool_occupancy_p50"] = _percentile(self._occ_samples, 50)
            summary["pool_occupancy_p95"] = _percentile(self._occ_samples, 95)
        if self.prefix_cache:
            summary["prefix_lookups"] = self.prefix_lookups
            summary["prefix_hit_rate"] = (
                self.prefix_hits / max(self.prefix_lookups, 1))
            summary["prefix_blocks_reused"] = self.prefix_blocks_reused
            summary["prefill_tokens_skipped"] = self.prefill_tokens_skipped
            # per-prefix residency: the pool attributes block-granular
            # hits/misses/evictions to each registered content hash
            stats = self._pool.prefix_stats.values()
            summary["prefix_block_hits"] = sum(s[0] for s in stats)
            summary["prefix_block_misses"] = sum(s[1] for s in stats)
            summary["prefix_block_evictions"] = sum(s[2] for s in stats)
            summary["prefix_hashes_tracked"] = len(self._pool.prefix_stats)
            summary["prefix_blocks_resident"] = len(self._pool.ready)
        if self.monitor is not None:
            total_j = sum(r.joules for r in self.finished)
            summary["joules_total"] = total_j
            summary["joules_per_request"] = total_j / max(
                len(self.finished), 1)
            summary["joules_per_token"] = total_j / max(out_tokens, 1)
            # achieved sampler health: the >= 5-10 Hz protocol requirement
            # is verifiable from the summary, and gaps the step function
            # backfilled with stale power are counted, not hidden
            res = self.monitor.result()
            summary["power_samples_per_sec"] = res.samples_per_sec
            summary["power_reads_dropped"] = res.dropped_reads
            # per-device split when the monitor keeps per-device ledgers
            # (DeviceMonitorGroup): each device's windowed integral over
            # the group window, so the list sums to result().joules — a
            # device that dropped every read contributes 0.0 J and its
            # drop count, never a crash
            by_dev = getattr(self.monitor, "result_by_device", None)
            if callable(by_dev):
                dev_results = by_dev()
                summary["joules_per_device"] = [
                    r.joules for r in dev_results]
                summary["power_samples_per_sec_per_device"] = [
                    r.samples_per_sec for r in dev_results]
                summary["power_reads_dropped_per_device"] = [
                    r.dropped_reads for r in dev_results]
        return summary
