"""Jitted serving-step builders (shared by the engine and the dry-run).

``make_prefill_step`` / ``make_decode_step`` return pure functions with the
exact signatures the multi-pod dry-run lowers; shardings are attached by the
caller (``launch.dryrun`` / ``serving.engine``).

``make_decode_sample_step`` is the engine's device-resident fast path: one
jitted function fuses the decode forward pass, per-slot sampling, PRNG key
splitting, position/budget bookkeeping and finish detection.  The host feeds
it a small ``state`` dict of per-slot device arrays and reads back a single
packed (3, B) int32 array per step — the only host<->device sync in the
steady-state decode loop.
"""

from __future__ import annotations

from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import model as model_lib
from repro.models.config import ModelConfig
from repro.serving.sampling import sample_slots_keyed, verify_slots_keyed


def make_prefill_step(cfg: ModelConfig) -> Callable:
    def prefill_step(params, batch, cache):
        return model_lib.prefill(cfg, params, batch, cache)

    return prefill_step


def make_decode_step(cfg: ModelConfig) -> Callable:
    def decode_step(params, token, position, cache):
        return model_lib.decode_step(cfg, params, token, position, cache)

    return decode_step


def make_serve_step(cfg: ModelConfig) -> Callable:
    """The dry-run `serve_step`: one new token against a seq_len KV cache."""
    return make_decode_step(cfg)


def init_slot_state(max_batch: int, seed: int = 0,
                    max_blocks: int = 0, spec_k: int = 0) -> Dict[str, jax.Array]:
    """Device-resident per-slot scheduler state for ``decode_sample_step``.

    tokens       (B, 1) int32  — next input token per slot
    positions    (B,)   int32  — next cache write position per slot
    active       (B,)   bool   — slot is serving a live request
    remaining    (B,)   int32  — new-token budget left (max_new minus emitted)
    temperature  (B,)   f32    — per-slot sampling temperature (<=0 greedy)
    top_k        (B,)   int32  — per-slot top-k (0 = no filter)
    eos          (B,)   int32  — per-slot EOS id (-1 = never)
    keys         (B, 2) uint32 — per-slot PRNG key chain, split on device
                 only when the slot emits a token (so a request's draws are
                 a pure function of its own key + emitted-token index,
                 independent of scheduling)
    block_tables (B, max_blocks) int32 — paged layout only (max_blocks > 0):
                 pool block per (slot, logical block); 0 = garbage block
    draft        (B, spec_k) int32 — speculative engines only (spec_k > 0):
                 the host drafter's proposed continuation tokens, replaced
                 wholesale before every verify dispatch
    draft_len    (B,)   int32  — valid leading draft tokens per slot
    """
    B = max_batch
    base = jax.random.PRNGKey(seed)
    state = {
        "tokens": jnp.zeros((B, 1), jnp.int32),
        "positions": jnp.zeros((B,), jnp.int32),
        "active": jnp.zeros((B,), jnp.bool_),
        "remaining": jnp.zeros((B,), jnp.int32),
        "temperature": jnp.zeros((B,), jnp.float32),
        "top_k": jnp.zeros((B,), jnp.int32),
        "eos": jnp.full((B,), -1, jnp.int32),
        "keys": jax.vmap(lambda i: jax.random.fold_in(base, i))(jnp.arange(B)),
    }
    if max_blocks > 0:
        state["block_tables"] = jnp.zeros((B, max_blocks), jnp.int32)
    if spec_k > 0:
        state["draft"] = jnp.zeros((B, spec_k), jnp.int32)
        state["draft_len"] = jnp.zeros((B,), jnp.int32)
    return state


def invalidate_slot(state: Dict[str, jax.Array], slot: int,
                    *, garbage_block: int = 0) -> Dict[str, jax.Array]:
    """Retire one slot's device row between steps (finish or preemption).

    The fused step keeps replaying every slot at a static shape, so a
    retired slot is not removed — it is *masked*: inactive (all cache and
    recurrent-state writes become no-ops), zero remaining budget, and, in
    the paged layout, the whole block-table row pointed back at the
    reserved garbage block so the slot's frozen idle writes can never
    land in a pool block that has been freed or handed to another
    request.  Everything else (token, position, key) is left frozen; the
    next occupant overwrites it when the slot is re-armed.
    """
    state = dict(state)
    state["active"] = state["active"].at[slot].set(False)
    state["remaining"] = state["remaining"].at[slot].set(0)
    if "block_tables" in state:
        state["block_tables"] = (
            state["block_tables"].at[slot].set(garbage_block))
    return state


def maybe_donate(fn: Callable, argnums: Tuple[int, ...]) -> Callable:
    """``jax.jit`` with buffer donation where the backend supports it.

    Donating the fused step's cache/state buffers lets XLA update the KV
    cache in place instead of allocating a fresh copy every step.  CPU has
    no donation support (jax would warn and ignore it), so fall back to a
    plain jit there.
    """
    if jax.default_backend() == "cpu":
        return jax.jit(fn)
    return jax.jit(fn, donate_argnums=argnums)


def make_decode_sample_step(cfg: ModelConfig, max_len: int,
                            k_max: int = 64) -> Callable:
    """Fused decode + sample + finish-detect step (jit once, replay forever).

    Returns ``step(params, state, cache) -> (state', cache', out)`` where
    ``out`` is a packed (3, B) int32 array:

      out[0] — token emitted this step per slot (garbage for idle slots)
      out[1] — 1 where the slot finished on this step (EOS / budget / cap)
      out[2] — 1 where the slot was active and therefore emitted out[0]

    Idle slots keep re-feeding their last token at a frozen position, so the
    compiled executable never changes shape — but all of their cache and
    recurrent-state writes are masked off (``update_mask=active`` threads
    down to every cache kind).  That matters with chunked prefill: a slot
    mid-prefill already owns its cache row / pool blocks, and the chunk
    cursor is concurrently filling them between decode steps.  Each slot
    also carries its own PRNG key chain, advanced only when it emits, so
    sampled streams are invariant to how prefills and decodes interleave.
    """

    def step(params, state: Dict[str, jax.Array], cache) -> Tuple[Dict, Dict, jax.Array]:
        return _decode_sample_body(cfg, max_len, k_max, params, state, cache)

    return step


def _decode_sample_body(cfg: ModelConfig, max_len: int, k_max: int,
                        params, state: Dict[str, jax.Array], cache):
    """Shared decode+sample+finish body of ``make_decode_sample_step`` and
    ``make_engine_step`` (identical math, so fused and split paths emit
    byte-identical streams)."""
    active = state["active"]
    logits, new_cache = model_lib.decode_step(
        cfg, params, state["tokens"], state["positions"], cache,
        block_tables=state.get("block_tables"), update_mask=active)
    split = jax.vmap(jax.random.split)(state["keys"])   # (B, 2, 2)
    tok = sample_slots_keyed(logits, state["temperature"], state["top_k"],
                             split[:, 0], k_max=k_max)

    act_i = active.astype(jnp.int32)
    tok = jnp.where(active, tok, state["tokens"][:, 0])
    positions = state["positions"] + act_i
    remaining = state["remaining"] - act_i
    hit_eos = (state["eos"] >= 0) & (tok == state["eos"])
    done = active & (hit_eos | (remaining <= 0) | (positions >= max_len - 1))

    new_state = dict(state)  # block_tables etc. pass through untouched
    new_state.update(
        tokens=tok[:, None],
        positions=positions,
        active=active & ~done,
        remaining=remaining,
        keys=jnp.where(active[:, None], split[:, 1], state["keys"]),
    )
    out = jnp.stack([tok, done.astype(jnp.int32), act_i])
    return new_state, new_cache, out


def _spec_verify_body(cfg: ModelConfig, max_len: int, k_max: int, spec_k: int,
                      params, state: Dict[str, jax.Array], cache):
    """Speculative decode: ONE batched multi-token forward scores every
    slot's draft window, then the unrolled acceptance chain emits 1 +
    accepted tokens per slot.

    The verify forward *is* the PR 6 length-masked chunk path: each slot's
    window ``[last_token, draft...]`` rides as a ragged (B, spec_k + 1) row
    (``lengths = draft_len + 1`` for active slots, 0 for idle/prefilling
    ones, whose rows write nothing), starting at the slot's next cache
    write position.  Window K/V is appended where it is computed — accepted
    positions hold exactly the K/V a step-at-a-time decode would have
    written; a rejected suffix's entries are simply re-written by the next
    window (``overwrite_from`` hides them from the contiguous attention
    read in the meantime, and paged reads causally mask them).  Returns
    ``(state', cache', out)`` with ``out`` a packed (B, 2 * (spec_k + 1) +
    1) int32 sync: emitted tokens | emission mask | finished flag.
    """
    active = state["active"]
    window = jnp.concatenate([state["tokens"], state["draft"]], axis=1)
    lengths = jnp.where(active, state["draft_len"] + 1, 0)
    logits, new_cache = model_lib.prefill_chunk(
        cfg, params, {"tokens": window}, cache, state["positions"],
        block_tables=state.get("block_tables"), lengths=lengths,
        overwrite_from=state["positions"], all_logits=True)
    res = verify_slots_keyed(
        logits, state["draft"], state["draft_len"], state["temperature"],
        state["top_k"], state["keys"], active=active,
        tokens0=state["tokens"][:, 0], positions=state["positions"],
        remaining=state["remaining"], eos=state["eos"],
        max_len=max_len, k_max=k_max)
    new_state = dict(state)  # block_tables / draft ride through untouched
    new_state.update(
        tokens=res["last_token"][:, None],
        positions=res["positions"],
        active=res["active"],
        remaining=res["remaining"],
        keys=res["keys"],
    )
    out = jnp.concatenate([
        res["tokens"],
        res["emit"].astype(jnp.int32),
        res["done"].astype(jnp.int32)[:, None],
    ], axis=1)
    return new_state, new_cache, out


def make_spec_decode_step(cfg: ModelConfig, max_len: int, k_max: int = 64,
                          spec_k: int = 4) -> Callable:
    """Fused speculative verify + accept + finish-detect step: the
    drop-in replacement for ``make_decode_sample_step`` when the engine
    runs with prompt-lookup drafting (``out`` is the packed spec sync of
    ``_spec_verify_body`` instead of the (3, B) decode sync)."""

    def step(params, state: Dict[str, jax.Array], cache):
        return _spec_verify_body(cfg, max_len, k_max, spec_k,
                                 params, state, cache)

    return step


def make_engine_step(cfg: ModelConfig, max_len: int,
                     k_max: int = 64, spec_k: int = 0) -> Callable:
    """The unified mixed prefill/decode step: ONE jitted device dispatch per
    engine step, however many prefill cursors are in flight.

    Returns ``step(params, state, chunk, cache) -> (state', cache', out,
    chunk_logits)``.  ``chunk`` is the packed FCFS cursor frontier, slot-
    aligned at a static width W:

      tokens (B, W) int32 — row s holds slot s's next prompt-chunk tokens
      start  (B,)   int32 — each row's absolute start position
      length (B,)   int32 — valid tokens in the row (0 = slot has no cursor)

    The chunk advance runs first (masked appends via ``prefill_chunk``'s
    ``lengths`` path — rows with length 0 write nothing), then the decode+
    sample+finish body runs over the chunk-updated cache exactly as in
    ``make_decode_sample_step`` — mirroring the legacy engine's
    chunks-then-decode ordering within a step, so token streams are
    byte-identical to the per-chunk dispatch path.  ``out`` is the same
    packed (3, B) int32 sync; ``chunk_logits`` (B, vocab) holds each row's
    last-valid-position logits, from which the host samples a finishing
    cursor's first token (rows mid-prompt or without a cursor are garbage
    and ignored).  A prefilling slot is inactive in ``state``, so the
    decode half's ``update_mask`` keeps it from disturbing the freshly
    appended chunk K/V — same invariant as the split path.

    ``spec_k > 0`` swaps the decode half for the speculative verify body
    (``_spec_verify_body``): the frontier advance and the batched draft
    verification stay ONE fused dispatch, so speculation preserves the
    <= 2 dispatches/step bound; ``out`` becomes the packed spec sync.
    """

    def step(params, state: Dict[str, jax.Array], chunk: Dict[str, jax.Array],
             cache):
        chunk_logits, cache = model_lib.prefill_chunk(
            cfg, params, {"tokens": chunk["tokens"]}, cache, chunk["start"],
            block_tables=state.get("block_tables"), lengths=chunk["length"])
        if spec_k > 0:
            new_state, new_cache, out = _spec_verify_body(
                cfg, max_len, k_max, spec_k, params, state, cache)
        else:
            new_state, new_cache, out = _decode_sample_body(
                cfg, max_len, k_max, params, state, cache)
        return new_state, new_cache, out, chunk_logits

    return step
