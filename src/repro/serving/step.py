"""Jitted serving-step builders (shared by the engine and the dry-run).

``make_prefill_step`` / ``make_decode_step`` return pure functions with the
exact signatures the multi-pod dry-run lowers; shardings are attached by the
caller (``launch.dryrun`` / ``serving.engine``).
"""

from __future__ import annotations

from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import model as model_lib
from repro.models.config import ModelConfig


def make_prefill_step(cfg: ModelConfig) -> Callable:
    def prefill_step(params, batch, cache):
        return model_lib.prefill(cfg, params, batch, cache)

    return prefill_step


def make_decode_step(cfg: ModelConfig) -> Callable:
    def decode_step(params, token, position, cache):
        return model_lib.decode_step(cfg, params, token, position, cache)

    return decode_step


def make_serve_step(cfg: ModelConfig) -> Callable:
    """The dry-run `serve_step`: one new token against a seq_len KV cache."""
    return make_decode_step(cfg)
