"""Async streaming client for the OpenAI-compatible server.

``stream_completion`` drives one ``POST /v1/completions`` with
``stream=true`` and records the *client-side* view of the request:

* ``send_time``        — just before the HTTP request is written;
* ``first_chunk_time`` — arrival of the first SSE chunk carrying tokens
  (the client-observed TTFT edge);
* ``last_chunk_time``  — arrival of the last token-carrying chunk (the
  client-observed TTLT edge; the final summary chunk and ``[DONE]``
  arrive after it and are excluded on purpose).

The final chunk's ``elana`` extension carries the engine's own
``perf_counter`` stamps for the same request.  ``perf_counter`` is
CLOCK_MONOTONIC — one clock per machine — so when client and server
share a host the client/engine deltas are directly meaningful:
``client_ttft >= engine_ttft`` always, and the gap is exactly the HTTP +
queueing overhead the serving path adds on top of the engine.
"""

from __future__ import annotations

import dataclasses
import json
import time
from typing import AsyncIterator, Dict, List, Sequence, Union

try:  # aiohttp is a dev/serving extra, not a core runtime dependency
    import aiohttp
except ImportError:  # pragma: no cover - exercised only without aiohttp
    aiohttp = None


@dataclasses.dataclass
class ClientRecord:
    """One streamed request as the client saw it."""
    send_time: float = 0.0
    first_chunk_time: float = 0.0
    last_chunk_time: float = 0.0
    tokens: List[int] = dataclasses.field(default_factory=list)
    chunks: int = 0
    finish_reason: str = ""
    usage: Dict = dataclasses.field(default_factory=dict)
    engine: Dict = dataclasses.field(default_factory=dict)  # ``elana`` payload
    joules: float = 0.0       # client-side attributed share (loadgen)
    error: str = ""

    # -- client-side latencies -------------------------------------------------
    @property
    def client_ttft_s(self) -> float:
        return self.first_chunk_time - self.send_time

    @property
    def client_ttlt_s(self) -> float:
        return self.last_chunk_time - self.send_time

    @property
    def client_tpot_s(self) -> float:
        n = len(self.tokens)
        if n < 2:
            return 0.0
        return (self.last_chunk_time - self.first_chunk_time) / (n - 1)

    # -- engine-side latencies (from the final chunk's elana payload) ----------
    @property
    def engine_ttft_s(self) -> float:
        return float(self.engine.get("engine_ttft_s") or 0.0)

    @property
    def engine_tpot_s(self) -> float:
        return float(self.engine.get("engine_tpot_s") or 0.0)


async def sse_data(resp) -> AsyncIterator[str]:
    """Yield the payload of each ``data:`` line of an SSE response."""
    async for raw in resp.content:
        line = raw.strip()
        if line.startswith(b"data:"):
            yield line[5:].strip().decode()


async def stream_completion(
    session: "aiohttp.ClientSession", base_url: str,
    prompt: Union[str, Sequence[int]], *, max_tokens: int = 16,
    temperature: float = 0.0, top_k: int = 0, eos_token: int = -1,
    model: str = "elana", timeout_s: float = 300.0,
) -> ClientRecord:
    """One streaming completion; never raises — errors land in ``.error``."""
    rec = ClientRecord()
    payload = {
        "model": model,
        "prompt": list(prompt) if not isinstance(prompt, str) else prompt,
        "max_tokens": max_tokens,
        "temperature": temperature,
        "top_k": top_k,
        "eos_token": eos_token,
        "stream": True,
    }
    rec.send_time = time.perf_counter()
    try:
        async with session.post(
                f"{base_url}/v1/completions", json=payload,
                timeout=aiohttp.ClientTimeout(total=timeout_s)) as resp:
            if resp.status != 200:
                rec.error = f"HTTP {resp.status}: {await resp.text()}"
                return rec
            async for data in sse_data(resp):
                if data == "[DONE]":
                    break
                now = time.perf_counter()
                obj = json.loads(data)
                ext = obj.get("elana", {})
                if "tokens" in ext and obj["choices"][0]["finish_reason"] is None:
                    if not rec.tokens:
                        rec.first_chunk_time = now
                    rec.last_chunk_time = now
                    rec.tokens.extend(ext["tokens"])
                    rec.chunks += 1
                else:  # final chunk: usage + engine-side stamps
                    rec.finish_reason = obj["choices"][0]["finish_reason"] or ""
                    rec.usage = obj.get("usage", {})
                    rec.engine = ext
    except Exception as e:  # connection reset, timeout, bad JSON ...
        rec.error = f"{type(e).__name__}: {e}"
    return rec


async def fetch_metrics(session: "aiohttp.ClientSession",
                        base_url: str) -> Dict:
    async with session.get(f"{base_url}/metrics") as resp:
        resp.raise_for_status()
        return await resp.json()
