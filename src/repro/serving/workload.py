"""Traffic generation for the serving engine (open-loop load).

The paper's headline metrics (TTFT/TPOT/TTLT, joules-per-token) are only
meaningful under realistic serving conditions, so instead of submitting all
prompts up front at t=0 the driver replays a *trace* of arrivals against
the wall clock (open-loop: arrival times do not depend on service times).

* ``WorkloadSpec`` + ``poisson_trace`` — Poisson arrivals at a target rate
  with configurable prompt / output length distributions (fixed, uniform,
  or lognormal), fully determined by the seed.
* ``replay_trace`` — deterministic replay of an explicit
  ``(time_s, prompt_len, max_new_tokens)`` schedule, for reproducible
  A/B runs and tests.
* ``shared_prefix_trace`` — mixture of K fixed system prompts with random
  user suffixes, the workload block-level prefix caching targets.
* ``bursty_trace`` — same-instant arrival waves that overcommit a
  load-sized KV pool, the workload preemption/recompute targets;
  ``estimate_concurrency`` turns a trace into the in-flight estimate
  ``--kv-num-blocks auto`` sizes the pool from.
* ``OpenLoopDriver`` — interleaves trace arrivals with engine steps:
  submits every request whose arrival time has passed, then runs one
  engine step; sleeps only when the engine is idle and the next arrival
  is in the future.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.sampling import SamplingParams


@dataclasses.dataclass(frozen=True)
class LengthDist:
    """Token-count distribution: fixed / uniform / lognormal."""

    kind: str = "fixed"          # "fixed" | "uniform" | "lognormal"
    mean: float = 64.0
    low: int = 1                 # uniform lower bound / global clamp
    high: int = 4096             # uniform upper bound / global clamp
    sigma: float = 0.5           # lognormal shape

    def sample(self, rng: np.random.Generator) -> int:
        if self.kind == "fixed":
            n = self.mean
        elif self.kind == "uniform":
            n = rng.integers(self.low, max(self.high, self.low + 1))
        elif self.kind == "lognormal":
            # parameterised so E[n] == mean
            mu = np.log(max(self.mean, 1.0)) - 0.5 * self.sigma ** 2
            n = rng.lognormal(mu, self.sigma)
        else:
            raise ValueError(f"unknown length dist {self.kind!r}")
        return int(np.clip(round(float(n)), self.low, self.high))


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    arrival_rate: float = 4.0            # requests / second (Poisson)
    num_requests: int = 8
    prompt_len: LengthDist = LengthDist(kind="uniform", low=4, high=48)
    output_len: LengthDist = LengthDist(kind="fixed", mean=16)
    temperature: float = 0.8
    top_k: int = 20
    eos_token: int = -1
    seed: int = 0


@dataclasses.dataclass
class Arrival:
    time_s: float                        # offset from trace start
    prompt: np.ndarray                   # (prompt_len,) int32
    params: SamplingParams


def poisson_trace(spec: WorkloadSpec, vocab_size: int) -> List[Arrival]:
    """Sampled arrival schedule; same (spec, vocab_size) -> same trace."""
    rng = np.random.default_rng(spec.seed)
    arrivals: List[Arrival] = []
    t = 0.0
    for _ in range(spec.num_requests):
        if spec.arrival_rate > 0:
            t += float(rng.exponential(1.0 / spec.arrival_rate))
        plen = spec.prompt_len.sample(rng)
        prompt = rng.integers(0, vocab_size, plen).astype(np.int32)
        arrivals.append(Arrival(
            time_s=t, prompt=prompt,
            params=SamplingParams(
                temperature=spec.temperature, top_k=spec.top_k,
                eos_token=spec.eos_token,
                max_new_tokens=spec.output_len.sample(rng))))
    return arrivals


def replay_trace(
    schedule: Sequence[Tuple[float, int, int]],
    vocab_size: int,
    *,
    seed: int = 0,
    temperature: float = 0.0,
    top_k: int = 0,
    eos_token: int = -1,
) -> List[Arrival]:
    """Deterministic replay of (time_s, prompt_len, max_new_tokens) rows."""
    rng = np.random.default_rng(seed)
    out = []
    for t, plen, max_new in schedule:
        prompt = rng.integers(0, vocab_size, int(plen)).astype(np.int32)
        out.append(Arrival(
            time_s=float(t), prompt=prompt,
            params=SamplingParams(temperature=temperature, top_k=top_k,
                                  eos_token=eos_token,
                                  max_new_tokens=int(max_new))))
    return out


def interference_trace(
    vocab_size: int,
    *,
    n_victims: int = 3,
    victim_plen: int = 8,
    victim_new: int = 256,
    long_plen: int = 448,
    long_new: int = 4,
    t_long: float = 0.0,
    seed: int = 0,
    temperature: float = 0.0,
) -> List[Arrival]:
    """The TTFT/TPOT-interference scenario: short "victim" requests that
    decode for a long time, plus one long-prompt request whose admission
    would stall them without chunked prefill.  The long request arrives
    last (at ``t_long``); ``benchmarks/serving_bench.py`` drives the trace
    closed-loop and measures the victims' p95 inter-token gap while the
    long prompt admits, chunked vs unchunked."""
    rng = np.random.default_rng(seed)
    arrivals = [
        Arrival(
            time_s=0.0,
            prompt=rng.integers(0, vocab_size, victim_plen).astype(np.int32),
            params=SamplingParams(temperature=temperature,
                                  max_new_tokens=victim_new))
        for _ in range(n_victims)
    ]
    arrivals.append(Arrival(
        time_s=float(t_long),
        prompt=rng.integers(0, vocab_size, long_plen).astype(np.int32),
        params=SamplingParams(temperature=temperature,
                              max_new_tokens=long_new)))
    return arrivals


def shared_prefix_trace(
    vocab_size: int,
    *,
    num_requests: int = 8,
    shared_prefix_len: int = 64,
    num_prefixes: int = 2,
    suffix_len: int = 16,
    max_new: int = 8,
    arrival_rate: float = 0.0,
    seed: int = 0,
    temperature: float = 0.0,
    top_k: int = 0,
    eos_token: int = -1,
) -> List[Arrival]:
    """Mixture-of-K shared system prompts: every request draws one of
    ``num_prefixes`` fixed prefix token arrays (the "system prompt" /
    few-shot preamble) and appends a fresh random ``suffix_len``-token user
    suffix.  This is the regime where block-level prefix caching pays:
    after the first request with a given prefix, every sharer skips the
    prefix's prefill entirely.

    The engine left-pads prompts to the bucket size, so cached blocks only
    match between requests with the same padded length — keep
    ``suffix_len`` fixed (as here) for maximal sharing.  Arrivals are
    Poisson at ``arrival_rate`` (all at t=0 when 0); same arguments, same
    trace."""
    rng = np.random.default_rng(seed)
    prefixes = [
        rng.integers(0, vocab_size, shared_prefix_len).astype(np.int32)
        for _ in range(num_prefixes)
    ]
    arrivals: List[Arrival] = []
    t = 0.0
    for _ in range(num_requests):
        if arrival_rate > 0:
            t += float(rng.exponential(1.0 / arrival_rate))
        k = int(rng.integers(0, num_prefixes))
        suffix = rng.integers(0, vocab_size, suffix_len).astype(np.int32)
        arrivals.append(Arrival(
            time_s=t, prompt=np.concatenate([prefixes[k], suffix]),
            params=SamplingParams(temperature=temperature, top_k=top_k,
                                  eos_token=eos_token,
                                  max_new_tokens=max_new)))
    return arrivals


def bursty_trace(
    vocab_size: int,
    *,
    bursts: int = 2,
    burst_size: int = 4,
    gap_s: float = 0.25,
    prompt_len: int = 48,
    max_new: int = 32,
    seed: int = 0,
    temperature: float = 0.0,
    top_k: int = 0,
    eos_token: int = -1,
) -> List[Arrival]:
    """The pool-overcommit workload: ``bursts`` waves of ``burst_size``
    same-instant arrivals, ``gap_s`` apart.  Each wave wants more KV
    blocks than a load-sized (non-worst-case) pool holds, so an engine
    without preemption either backpressures the whole wave behind FCFS
    admission or must be provisioned for the peak; with
    ``preemption="recompute"`` the wave admits, overcommits, and the
    newest requests are preempted/recomputed as the pool breathes.  Same
    arguments, same trace."""
    rng = np.random.default_rng(seed)
    arrivals: List[Arrival] = []
    for b in range(bursts):
        for _ in range(burst_size):
            prompt = rng.integers(0, vocab_size, prompt_len).astype(np.int32)
            arrivals.append(Arrival(
                time_s=b * gap_s, prompt=prompt,
                params=SamplingParams(temperature=temperature, top_k=top_k,
                                      eos_token=eos_token,
                                      max_new_tokens=max_new)))
    return arrivals


def lookup_friendly_trace(
    vocab_size: int,
    *,
    num_requests: int = 8,
    motif_len: int = 8,
    repeats: int = 4,
    max_new: int = 32,
    arrival_rate: float = 0.0,
    seed: int = 0,
    temperature: float = 0.0,
    top_k: int = 0,
    eos_token: int = -1,
) -> List[Arrival]:
    """The prompt-lookup speculative-decoding showcase: each prompt is one
    random ``motif_len``-token motif tiled ``repeats`` times.  A model
    continuing such a prompt tends to keep cycling the motif (greedy
    decode on self-similar context collapses into the loop), and every
    generated token's trailing n-gram then re-occurs earlier in the
    stream — exactly what ``speculative="lookup"`` drafts from, so accept
    rates approach 1 and one verify dispatch emits whole motif stretches.
    Structurally repetitive prompts like this stand in for the
    summarize/extract/code-edit workloads where the output quotes its
    input.  Arrivals are Poisson at ``arrival_rate`` (all at t=0 when 0);
    same arguments, same trace."""
    rng = np.random.default_rng(seed)
    arrivals: List[Arrival] = []
    t = 0.0
    for _ in range(num_requests):
        if arrival_rate > 0:
            t += float(rng.exponential(1.0 / arrival_rate))
        motif = rng.integers(0, vocab_size, motif_len).astype(np.int32)
        arrivals.append(Arrival(
            time_s=t, prompt=np.tile(motif, repeats),
            params=SamplingParams(temperature=temperature, top_k=top_k,
                                  eos_token=eos_token,
                                  max_new_tokens=max_new)))
    return arrivals


def estimate_concurrency(arrivals: Sequence[Arrival], max_batch: int,
                         q: float = 95.0) -> int:
    """p-th percentile of the in-flight request count a trace implies,
    for ``cache_lib.suggest_num_blocks``.

    Service times are unknown before the run, so assume the engine
    exactly sustains the offered token load: request *i* occupies a slot
    for ``tokens_i / R`` seconds with ``R = total_tokens / trace_span``.
    The in-flight count is sampled at every arrival instant, capped at
    ``max_batch`` (the engine cannot exceed its slots).  A closed-loop
    trace (zero span) saturates: every slot is assumed live."""
    if not arrivals:
        return 1
    t = np.asarray([a.time_s for a in arrivals], np.float64)
    tokens = np.asarray(
        [len(a.prompt) + a.params.max_new_tokens for a in arrivals],
        np.float64)
    span = float(t.max() - t.min())
    if span <= 0.0:
        return max_batch
    rate = tokens.sum() / span
    end = t + tokens / rate
    counts = [int(np.sum((t <= now) & (now < end))) for now in t]
    counts = sorted(min(c, max_batch) for c in counts)
    k = max(int(-(-len(counts) * q // 100)), 1) - 1
    return max(counts[min(k, len(counts) - 1)], 1)


class OpenLoopDriver:
    """Replay a trace against the wall clock while stepping the engine."""

    def __init__(self, engine, arrivals: Iterable[Arrival],
                 *, time_scale: float = 1.0, max_steps: int = 100_000):
        self.engine = engine
        self.arrivals = sorted(arrivals, key=lambda a: a.time_s)
        self.time_scale = time_scale     # >1 compresses the trace (faster)
        self.max_steps = max_steps

    def run(self) -> List:
        eng = self.engine
        t0 = time.perf_counter()
        i, steps = 0, 0
        n = len(self.arrivals)
        while (i < n or eng.busy) and steps < self.max_steps:
            now = (time.perf_counter() - t0) * self.time_scale
            while i < n and self.arrivals[i].time_s <= now:
                a = self.arrivals[i]
                eng.submit(a.prompt, a.params)
                i += 1
            if eng.busy:
                eng.step()
                steps += 1
            elif i < n:
                wait = (self.arrivals[i].time_s - now) / self.time_scale
                time.sleep(min(max(wait, 0.0), 0.05))
        eng.flush()
        return eng.finished
