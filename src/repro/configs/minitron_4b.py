"""Minitron-4B — width-pruned Nemotron-4 [arXiv:2407.14679; hf].

Nemotron family: squared-ReLU non-gated FFN, untied embeddings.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b", family="dense", source="arXiv:2407.14679; hf",
    num_layers=32, d_model=3072, num_heads=24, num_kv_heads=8, head_dim=128,
    d_ff=9216, vocab_size=256_000,
    mlp_act="relu2", mlp_gated=False, tie_embeddings=False,
)

SMOKE = CONFIG.replace(
    num_layers=4, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, dtype="float32", param_dtype="float32",
)
