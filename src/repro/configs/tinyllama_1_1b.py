"""TinyLlama-1.1B — Llama-2 architecture, small [arXiv:2401.02385; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="tinyllama-1.1b", family="dense", source="arXiv:2401.02385; hf",
    num_layers=22, d_model=2048, num_heads=32, num_kv_heads=4, head_dim=64,
    d_ff=5632, vocab_size=32_000, tie_embeddings=False,
)

SMOKE = CONFIG.replace(
    num_layers=3, d_model=64, num_heads=8, num_kv_heads=2, head_dim=8,
    d_ff=128, vocab_size=256, dtype="float32", param_dtype="float32",
)
