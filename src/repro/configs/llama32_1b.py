"""Llama-3.2-1B — paper Table 4 (Orin Nano) model."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b", family="dense", source="Meta 2024 (paper §2, Table 4)",
    num_layers=16, d_model=2048, num_heads=32, num_kv_heads=8, head_dim=64,
    d_ff=8192, vocab_size=128_256, rope_theta=500_000.0, tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, dtype="float32", param_dtype="float32",
)
