"""Architecture registry: ``--arch <id>`` lookup for every supported config.

``ASSIGNED`` is the ten-architecture pool from the assignment; ``PAPER`` is
the three models profiled in the ELANA paper itself (Tables 2-4).
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig, SHAPES, ShapeConfig  # noqa: F401

_MODULES: Dict[str, str] = {
    # assigned pool
    "minitron-4b": "minitron_4b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "command-r-plus-104b": "command_r_plus_104b",
    "llava-next-34b": "llava_next_34b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "xlstm-1.3b": "xlstm_1_3b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    # the paper's own models
    "llama3.1-8b": "llama31_8b",
    "qwen2.5-7b": "qwen25_7b",
    "nemotron-h-8b": "nemotron_h_8b",
    "llama3.2-1b": "llama32_1b",
    "qwen2.5-1.5b": "qwen25_1_5b",
}

ASSIGNED: List[str] = list(_MODULES)[:10]
PAPER: List[str] = list(_MODULES)[10:]


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    cfg = mod.SMOKE if smoke else mod.CONFIG
    return cfg.validate()


def list_archs() -> List[str]:
    return list(_MODULES)
