"""Qwen-2.5-7B — paper Table 2/3 model [arXiv:2409.12186]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-7b", family="dense", source="arXiv:2409.12186 (paper §2)",
    num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4, head_dim=128,
    d_ff=18_944, vocab_size=152_064, qkv_bias=True, tie_embeddings=False,
)

SMOKE = CONFIG.replace(
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, dtype="float32", param_dtype="float32",
)
