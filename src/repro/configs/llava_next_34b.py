"""LLaVA-NeXT-34B backbone (Yi-34B trunk) [hf:llava-hf; unverified].

The vision tower + anyres tiling is a STUB per the assignment: input_specs()
supplies precomputed patch embeddings (num_vision_tokens, d_model) that are
prepended to the token sequence.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b", family="vlm",
    source="hf:llava-hf/llava-v1.6-34b-hf; unverified",
    num_layers=60, d_model=7168, num_heads=56, num_kv_heads=8, head_dim=128,
    d_ff=20_480, vocab_size=64_000, tie_embeddings=False,
    num_vision_tokens=576,
)

SMOKE = CONFIG.replace(
    num_layers=3, d_model=64, num_heads=8, num_kv_heads=2, head_dim=8,
    d_ff=128, vocab_size=256, num_vision_tokens=4,
    dtype="float32", param_dtype="float32",
)
