"""Qwen2.5-1.5B — paper Table 4 (Orin Nano) model."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-1.5b", family="dense", source="paper §2, Table 4",
    num_layers=28, d_model=1536, num_heads=12, num_kv_heads=2, head_dim=128,
    d_ff=8960, vocab_size=151_936, qkv_bias=True, tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, dtype="float32", param_dtype="float32",
)
