"""RecurrentGemma-2B — Griffin: RG-LRU + local attention 1:2
[arXiv:2402.19427; hf].

MQA (kv=1) sliding-window 2048 attention every third layer; bounded cache ->
runs long_500k.  Gemma-style scaled embeddings + final logit soft-cap.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid", source="arXiv:2402.19427; hf",
    num_layers=26, d_model=2560, num_heads=10, num_kv_heads=1, head_dim=256,
    d_ff=7680, vocab_size=256_000,
    block_pattern=("rglru", "rglru", "local_attn"), sliding_window=2048,
    mlp_act="gelu", tie_embeddings=True, emb_scale=True, logit_softcap=30.0,
    lru_width=2560,
)

SMOKE = CONFIG.replace(
    num_layers=4, d_model=80, num_heads=2, num_kv_heads=1, head_dim=16,
    d_ff=128, vocab_size=256, sliding_window=16, lru_width=80,
    recurrent_chunk=16, dtype="float32", param_dtype="float32",
)
