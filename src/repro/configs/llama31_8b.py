"""Llama-3.1-8B — paper Table 2/3 model [Meta 2024]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3.1-8b", family="dense", source="Meta 2024 (paper §2)",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14_336, vocab_size=128_256, rope_theta=500_000.0,
    tie_embeddings=False,
)

SMOKE = CONFIG.replace(
    num_layers=3, d_model=64, num_heads=8, num_kv_heads=2, head_dim=8,
    d_ff=128, vocab_size=256, dtype="float32", param_dtype="float32",
)
