"""Qwen3-30B-A3B — MoE 128 experts top-8, normalized gates
[hf:Qwen/Qwen3-30B-A3B]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe", source="hf:Qwen/Qwen3-30B-A3B; hf",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=4, head_dim=128,
    d_ff=768, vocab_size=151_936,
    num_experts=128, num_experts_per_tok=8, tie_embeddings=False,
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=8, num_kv_heads=2, head_dim=16,
    d_ff=32, vocab_size=256, num_experts=8, num_experts_per_tok=2,
    dtype="float32", param_dtype="float32",
)
