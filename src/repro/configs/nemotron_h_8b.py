"""Nemotron-H-8B — hybrid Mamba2/attention, paper Table 2/3 model
[arXiv:2504.03624].

TPU adaptation note (DESIGN.md §4): the Mamba-2/SSD blocks are represented
by the chunkwise matrix-memory cell (mLSTM) — the same gated linear-
recurrence + matrix-state family — with rec_heads=128, head dim 64 matching
Nemotron-H's d_inner=8192 SSM geometry.  6 attention layers (kv=8, hd=128)
interleave every 8th layer, matching the paper's KV-cache scaling.
Param bytes land within ~1% of the paper's 16.20 GB (the stand-in block is
slightly leaner than Mamba-2's in_proj; FFN-only layers interleave as in the
real model); noted in EXPERIMENTS §Paper-validation.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-h-8b", family="hybrid", source="arXiv:2504.03624 (paper §2)",
    num_layers=52, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=21_504, vocab_size=131_072,
    # 52 layers = 2 x this 26-slot pattern: 32 Mamba2 stand-ins (mLSTM),
    # 14 FFN-only layers, 6 attention layers (matches the paper's KV scaling)
    block_pattern=("mlstm", "mlstm", "ffn") * 7 + ("mlstm", "attn", "mlstm", "attn", "attn"),
    mlstm_proj_factor=2.0, rec_heads=128,
    mlp_act="relu2", mlp_gated=False, tie_embeddings=False,
)

SMOKE = CONFIG.replace(
    num_layers=8, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, rec_heads=8, recurrent_chunk=16,
    block_pattern=("mlstm", "ffn", "attn", "mlstm"),
    dtype="float32", param_dtype="float32",
)
