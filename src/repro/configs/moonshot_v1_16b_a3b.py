"""Moonshot/Moonlight-16B-A3B — MoE 64 experts top-6, 2 shared experts
(DeepSeek-style) [hf:moonshotai/Moonlight-16B-A3B]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    source="hf:moonshotai/Moonlight-16B-A3B; hf",
    num_layers=48, d_model=2048, num_heads=16, num_kv_heads=16, head_dim=128,
    d_ff=1408, vocab_size=163_840,
    num_experts=64, num_experts_per_tok=6, num_shared_experts=2,
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=32, vocab_size=256, num_experts=8, num_experts_per_tok=2,
    num_shared_experts=1, dtype="float32", param_dtype="float32",
)
