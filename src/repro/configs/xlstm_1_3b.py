"""xLSTM-1.3B — mLSTM + sLSTM blocks at 7:1 [arXiv:2405.04517; unverified].

Pure recurrent stack (d_ff=0 per the assignment: projections live inside the
xLSTM blocks).  State cache is O(1) in sequence length -> runs long_500k.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm", source="arXiv:2405.04517; unverified",
    num_layers=48, d_model=2048, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50_304,
    block_pattern=("mlstm",) * 7 + ("slstm",),
    mlstm_proj_factor=2.0, tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    num_layers=8, d_model=64, num_heads=4, num_kv_heads=4,
    vocab_size=256, recurrent_chunk=16, dtype="float32", param_dtype="float32",
)
