"""SeamlessM4T-large-v2 transformer backbone [arXiv:2308.11596; hf].

Encoder-decoder; the conformer speech frontend is a STUB — input_specs()
supplies precomputed frame embeddings (B, T_enc, d_model) to the encoder.
Classic (non-gated) ReLU FFN.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="audio", source="arXiv:2308.11596; hf",
    num_layers=24, num_encoder_layers=24,
    d_model=1024, num_heads=16, num_kv_heads=16, head_dim=64,
    d_ff=8192, vocab_size=256_206,
    mlp_act="relu", mlp_gated=False, tie_embeddings=True, audio_frontend=True,
)

SMOKE = CONFIG.replace(
    num_layers=2, num_encoder_layers=2, d_model=64, num_heads=4,
    num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=256,
    dtype="float32", param_dtype="float32",
)
