"""Command-R+ 104B — GQA, no bias, parallel attn/FFN blocks, tied embeddings
[hf:CohereForAI/c4ai-command-r-plus; unverified]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b", family="dense",
    source="hf:CohereForAI/c4ai-command-r-v01; unverified",
    num_layers=64, d_model=12288, num_heads=96, num_kv_heads=8, head_dim=128,
    d_ff=33_792, vocab_size=256_000, tie_embeddings=True, parallel_block=True,
)

SMOKE = CONFIG.replace(
    num_layers=3, d_model=64, num_heads=8, num_kv_heads=2, head_dim=8,
    d_ff=128, vocab_size=256, dtype="float32", param_dtype="float32",
)
