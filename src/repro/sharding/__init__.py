from repro.sharding.rules import shard, use_mesh, logical_to_pspec  # noqa: F401
