"""Logical-axis sharding rules (MaxText-style) for the production mesh.

Every parameter and activation in the model code is annotated with *logical*
axis names ("embed", "heads", "ffn", ...).  A ``Rules`` object maps those to
physical mesh axes; ``logical_to_pspec`` turns an axis tuple into a
``PartitionSpec``.  The model code itself never mentions physical axes, so
the same code lowers on a 1-device CPU, a 16x16 pod, or a 2x16x16 multi-pod
mesh.

Rules are *mesh-aware*: a logical axis is only mapped onto a physical axis if
the corresponding dimension is divisible by that axis size (XLA tolerates
uneven sharding via padding, but for small dims like kv_heads=1 the padding
waste is worse than replication, so we drop the mapping instead).
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

MeshAxes = Union[None, str, Tuple[str, ...]]

# Default logical->physical mapping.  "data_axes" is (pod, data) when the pod
# axis exists so that FSDP and the batch dim span pods.
DEFAULT_RULES: Dict[str, MeshAxes] = {
    # activations
    "batch": ("pod", "data"),
    "seq": None,             # sequence kept whole by default (see "seq_sp")
    "seq_sp": "model",       # sequence-parallel alternative for long prefill
    "act_embed": None,
    "act_heads": "model",
    "act_kv": None,
    "act_ffn": "model",
    "vocab_out": "model",
    # params
    "embed": ("pod", "data"),   # FSDP axis
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "qkv": "model",          # fused per-head projections
    "ffn": "model",
    "experts": "model",      # expert parallelism
    "expert_ffn": None,
    "lru": "model",
    "conv": None,
    "layers": None,          # stacked-scan leading axis, never sharded
}


# Weight-stationary serving rules: decode-step activations are tiny (one
# token per sequence), so replicating them across the data axis turns the
# per-layer FSDP weight all-gathers into small activation all-reduces
# (EXPERIMENTS §Perf iteration: command-r decode).  Params/caches keep their
# 2D sharding.
SERVE_RULES: Dict[str, MeshAxes] = {
    **DEFAULT_RULES,
    "batch": None,
    "act_embed": "data",     # residual stream d-sharded over data: every
    "act_heads": "model",    # matmul contracts a local dim on both mesh axes
    "act_ffn": "model",
    "vocab_out": "model",
}


def _retag(rules: Dict[str, MeshAxes], old: str, new: str) -> Dict[str, MeshAxes]:
    """Rule set with every reference to physical axis ``old`` renamed ``new``."""
    def sub(spec: MeshAxes) -> MeshAxes:
        if spec == old:
            return new
        if isinstance(spec, tuple):
            return tuple(new if a == old else a for a in spec)
        return spec
    return {k: sub(v) for k, v in rules.items()}


# Tensor-parallel serving rules for a single-axis ("tp",) mesh: heads, FFN
# hidden, experts, and the output vocab shard over ``tp``; everything mapped
# to axes the mesh lacks ("pod"/"data"/"model") degrades to replication via
# ``_physical_axes``.  In particular the batch/slot dims and the sampling
# PRNG state stay replicated, so the engine's packed host sync is still one
# transfer of a fully-replicated array.
TP_SERVE_RULES: Dict[str, MeshAxes] = _retag(SERVE_RULES, "model", "tp")


class _State(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: Dict[str, MeshAxes] = dict(DEFAULT_RULES)


_STATE = _State()


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], rules: Optional[Dict[str, MeshAxes]] = None):
    """Activate a mesh + rule set for model tracing/lowering."""
    prev = (_STATE.mesh, _STATE.rules)
    _STATE.mesh = mesh
    _STATE.rules = dict(DEFAULT_RULES if rules is None else rules)
    try:
        if mesh is not None:
            with mesh:
                yield
        else:
            yield
    finally:
        _STATE.mesh, _STATE.rules = prev


def current_mesh() -> Optional[Mesh]:
    return _STATE.mesh


def _physical_axes(mesh: Mesh, spec: MeshAxes) -> Optional[Tuple[str, ...]]:
    """Keep only axes present in the mesh; None if nothing survives."""
    if spec is None:
        return None
    axes = (spec,) if isinstance(spec, str) else tuple(spec)
    axes = tuple(a for a in axes if a in mesh.axis_names)
    return axes or None


def _axis_size(mesh: Mesh, axes: Tuple[str, ...]) -> int:
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def logical_to_pspec(
    logical: Sequence[Optional[str]],
    dims: Optional[Sequence[int]] = None,
    mesh: Optional[Mesh] = None,
    rules: Optional[Dict[str, MeshAxes]] = None,
) -> PartitionSpec:
    """Map logical axis names to a PartitionSpec for the active mesh.

    ``dims`` (matching shape) enables the divisibility check; without it the
    mapping is taken as-is.  Each physical axis may be used at most once in a
    spec (PartitionSpec requirement) — first logical axis wins.
    """
    mesh = mesh or _STATE.mesh
    rules = rules if rules is not None else _STATE.rules
    if mesh is None:
        return PartitionSpec()
    used = set()
    out = []
    for i, name in enumerate(logical):
        spec = rules.get(name) if name else None
        axes = _physical_axes(mesh, spec) if spec else None
        if axes:
            axes = tuple(a for a in axes if a not in used)
        if axes and dims is not None:
            if dims[i] % _axis_size(mesh, axes) != 0:
                # try a shrinking suffix/prefix before giving up
                axes = tuple(
                    a for a in axes if dims[i] % mesh.shape[a] == 0
                )[:1] or None
        if axes:
            used.update(axes)
            out.append(axes if len(axes) > 1 else axes[0])
        else:
            out.append(None)
    return PartitionSpec(*out)


def shard(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Activation sharding constraint by logical axis names (no-op w/o mesh)."""
    mesh = _STATE.mesh
    if mesh is None:
        return x
    pspec = logical_to_pspec(logical, dims=x.shape, mesh=mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, pspec))


def named_sharding(logical: Sequence[Optional[str]], dims=None) -> Optional[NamedSharding]:
    mesh = _STATE.mesh
    if mesh is None:
        return None
    return NamedSharding(mesh, logical_to_pspec(logical, dims=dims, mesh=mesh))


def tree_pspecs(axes_tree, shapes_tree=None, mesh=None, rules=None):
    """Map a pytree of logical-axis tuples to PartitionSpecs.

    ``axes_tree`` leaves are tuples of logical names; ``shapes_tree`` (same
    structure, leaves = shape tuples) enables divisibility checks.
    """
    mesh = mesh or _STATE.mesh
    if shapes_tree is None:
        return jax.tree.map(
            lambda ax: logical_to_pspec(ax, mesh=mesh, rules=rules),
            axes_tree,
            is_leaf=lambda l: isinstance(l, tuple) and all(
                isinstance(a, (str, type(None))) for a in l),
        )
    return jax.tree.map(
        lambda ax, shp: logical_to_pspec(ax, dims=shp, mesh=mesh, rules=rules),
        axes_tree,
        shapes_tree,
        is_leaf=lambda l: isinstance(l, tuple) and all(
            isinstance(a, (str, type(None))) for a in l),
    )
