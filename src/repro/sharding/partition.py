"""Partitioning helpers: params/opt-state PartitionSpecs from the logical
axes tree, batch sharding for inputs, and jit wrappers with shardings.
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.sharding.rules import logical_to_pspec, tree_pspecs


def _is_axes_leaf(l) -> bool:
    return isinstance(l, tuple) and all(isinstance(a, (str, type(None))) for a in l)


def param_pspecs(axes_tree, shape_tree, mesh: Mesh, rules=None):
    """PartitionSpec tree for params (divisibility-checked against shapes)."""
    shapes = jax.tree.map(lambda s: tuple(s.shape), shape_tree)
    return jax.tree.map(
        lambda ax, shp: logical_to_pspec(ax, dims=shp, mesh=mesh, rules=rules),
        axes_tree, shapes, is_leaf=_is_axes_leaf,
    )


def param_shardings(axes_tree, shape_tree, mesh: Mesh, rules=None):
    specs = param_pspecs(axes_tree, shape_tree, mesh, rules)
    return jax.tree.map(
        lambda ps: NamedSharding(mesh, ps),
        specs, is_leaf=lambda l: isinstance(l, PartitionSpec),
    )


def opt_state_shardings(param_sh, opt_state_shapes, mesh: Mesh):
    """Moments shard like their params; scalars replicate."""
    replicated = NamedSharding(mesh, PartitionSpec())

    def match(path_shape):
        return path_shape

    # OptState(mu, nu, count): mirror params for mu/nu.
    return type(opt_state_shapes)(
        mu=param_sh, nu=param_sh,
        count=replicated,
    )


def batch_pspec(mesh: Mesh, extra_dims: int = 1) -> PartitionSpec:
    """Inputs: batch on (pod, data), everything else replicated."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return PartitionSpec(axes if len(axes) > 1 else (axes[0] if axes else None),
                         *([None] * extra_dims))


def batch_shardings(batch_shapes, mesh: Mesh):
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp = 1
    for a in axes:
        dp *= mesh.shape[a]
    data_axes = axes if len(axes) > 1 else (axes[0] if axes else None)

    def leaf(s):
        nd = len(s.shape)
        if nd == 0 or dp <= 1 or s.shape[0] % dp != 0:
            return NamedSharding(mesh, PartitionSpec(*([None] * nd)))
        return NamedSharding(mesh, PartitionSpec(data_axes, *([None] * (nd - 1))))

    return jax.tree.map(leaf, batch_shapes)


def cache_shardings(cache_shapes, mesh: Mesh):
    """KV-cache: batch dim on (pod, data).

    Leaves under ``groups`` are scan-stacked — batch sits at axis 1; under
    ``rest`` it is axis 0.  Uneven batch dims fall back to replication.

    On a tensor-parallel serving mesh (axis ``tp``) KV heads shard over the
    tp axis — including the paged pool leaves ``kp``/``vp``, whose *block*
    axis is never sharded (block tables are host-managed and index every
    device's pool identically; each device holds its head-shard of every
    block).  On the training mesh, heads dims stay replicated across
    ``model`` by default — the serve-path hillclimb (EXPERIMENTS §Perf)
    revisits this.
    """
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    data_axes = axes if len(axes) > 1 else (axes[0] if axes else None)
    dp = 1
    for a in axes:
        dp *= mesh.shape[a]

    mp_name = "model" if "model" in mesh.axis_names else "tp"
    model_size = mesh.shape.get(mp_name, 1)
    has_model = model_size > 1

    def leaf(path, s):
        keys = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        b_axis = 1 if (keys and keys[0] == "groups") else 0
        name = keys[-1]
        nd = len(s.shape)
        spec: list = [None] * nd
        if dp > 1 and nd > b_axis and s.shape[b_axis] % dp == 0:
            spec[b_axis] = data_axes

        def try_model(*idxs):
            """First dim (in preference order) divisible by the model axis."""
            for i in idxs:
                if 0 <= i < nd and spec[i] is None and \
                        s.shape[i] % model_size == 0 and s.shape[i] >= model_size:
                    spec[i] = mp_name
                    return

        # model-parallel dim: kv heads when they divide, else the KV length
        # (sequence-parallel cache — flash-decoding-style partial softmax);
        # recurrent heads, else the state feature dim
        if has_model:
            if name in ("kp", "vp") and nd >= 4:
                try_model(nd - 2)                  # pool heads only, never blocks
            elif name in ("k", "v", "cross_k", "cross_v") and nd >= b_axis + 4:
                try_model(nd - 2, b_axis + 1)      # H, else L
            elif name == "pos" and nd == b_axis + 2:
                pass                               # must mirror k/v L-sharding? kept replicated
            elif name in ("C", "n", "m", "c", "h"):
                if name == "h" and nd == b_axis + 2:
                    try_model(nd - 1)              # rglru h: (..., B, W)
                elif nd >= b_axis + 2:
                    try_model(b_axis + 1, b_axis + 2)  # H, else Dk/Dh
            elif name == "conv" and nd >= b_axis + 3:
                try_model(nd - 1)                  # (..., B, K-1, W)
        return NamedSharding(mesh, PartitionSpec(*spec))

    return jax.tree_util.tree_map_with_path(leaf, cache_shapes)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, PartitionSpec())
