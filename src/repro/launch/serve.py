"""Production serving driver: open-loop traffic against the device-resident
continuous-batching engine, with per-request energy attribution.

    python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
        --arrival-rate 4 --requests 8 --max-new 16 --max-batch 4

``--arrival-rate 0`` submits every request up front (the legacy closed-loop
mode); otherwise arrivals follow a Poisson process at the given rate.
``--replay t:plen:max_new,t:plen:max_new,...`` replays a deterministic
schedule instead.  Energy is sampled by a ``core.energy`` power reader
(``--power-reader proc|model|synthetic|none``) and attributed to requests
proportionally to the tokens each emitted within every measured window.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import report
from repro.core.energy import (DeviceMonitorGroup, ModelReader, PowerMonitor,
                               ProcStatReader, SyntheticReader)
from repro.launch.mesh import make_host_mesh, make_tp_mesh
from repro.models import model as model_lib
from repro.serving.engine import ServingEngine
from repro.models import cache as cache_lib
from repro.serving.workload import (LengthDist, OpenLoopDriver, WorkloadSpec,
                                    bursty_trace, estimate_concurrency,
                                    lookup_friendly_trace, poisson_trace,
                                    replay_trace, shared_prefix_trace)
from repro.sharding import rules


def _make_reader(kind: str):
    if kind == "proc":
        return ProcStatReader()
    if kind == "model":
        return ModelReader(idle_watts=10.0, tdp_watts=65.0)
    if kind == "synthetic":
        return SyntheticReader(lambda t: 42.0)
    return None


def _make_monitor(kind: str, n_devices: int):
    """One PowerMonitor, or — under --tp — a per-device monitor group whose
    windowed joules tile exactly to the aggregate (on CPU each per-device
    reader is a proxy; real NVML/jtop readers bind one device each)."""
    if kind == "none":
        return None
    if n_devices > 1:
        return DeviceMonitorGroup([_make_reader(kind)
                                   for _ in range(n_devices)])
    return PowerMonitor(_make_reader(kind))


def _parse_replay(text: str):
    rows = []
    for item in text.split(","):
        try:
            t, plen, max_new = item.split(":")
            rows.append((float(t), int(plen), int(max_new)))
        except ValueError:
            raise ValueError(
                f"bad --replay item {item!r}: expected t:plen:max_new")
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="Poisson arrivals/sec; 0 = submit all up front")
    ap.add_argument("--prompt-len-dist", default="uniform",
                    choices=["fixed", "uniform", "lognormal"])
    ap.add_argument("--prompt-len-mean", type=float, default=24.0)
    ap.add_argument("--replay", default="",
                    help="deterministic schedule t:plen:max_new,... "
                         "(overrides --arrival-rate)")
    ap.add_argument("--power-reader", default="proc",
                    choices=["proc", "model", "synthetic", "none"])
    ap.add_argument("--http-port", type=int, default=0,
                    help="serve over HTTP instead of replaying a trace: "
                         "start the OpenAI-compatible server (POST "
                         "/v1/completions with stream=true SSE, /v1/models, "
                         "/metrics) on this port and run until Ctrl-C "
                         "(0 = off; workload flags are ignored)")
    ap.add_argument("--http-host", default="127.0.0.1",
                    help="bind address for --http-port")
    ap.add_argument("--cache-layout", default="contiguous",
                    choices=["contiguous", "paged"],
                    help="KV layout: worst-case contiguous slots or a "
                         "shared block pool with per-slot block tables")
    ap.add_argument("--kv-block-size", type=int, default=16)
    ap.add_argument("--kv-num-blocks", default="0",
                    help="paged pool size in blocks; 0 = worst case, "
                         "'auto' = size from the workload trace (p95 "
                         "sequence length x estimated concurrency, "
                         "cache.suggest_num_blocks — pair with "
                         "--preemption recompute so a bursty tail "
                         "preempts instead of failing); smaller pools "
                         "trade pressure handling for device memory")
    ap.add_argument("--preemption", default="off",
                    choices=["off", "recompute"],
                    help="KV pool overcommit policy (paged layout only): "
                         "'off' reserves each request's worst case at "
                         "admission and backpressures; 'recompute' "
                         "reserves lazily, grows per decode step, and on "
                         "a dry pool preempts the newest in-flight "
                         "request (never the head-of-line), re-admitting "
                         "it later by recomputing its prompt + generated "
                         "prefix")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked prefill: split prompt prefills into "
                         "chunks of this many tokens, interleaved with "
                         "decode steps (0 = whole-prompt admission); bounds "
                         "how long in-flight decodes stall on a new prompt")
    ap.add_argument("--prefill-budget", type=int, default=0,
                    help="prompt tokens of chunk work per engine step "
                         "(0 = one chunk; clamped to >= --prefill-chunk); "
                         "only meaningful with --prefill-chunk")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="block-level prefix caching (paged layout only): "
                         "hash full prompt blocks and share resident "
                         "read-only pool blocks across requests with a "
                         "common prefix, skipping their prefill")
    ap.add_argument("--shared-prefix-len", type=int, default=0,
                    help="generate a shared-prefix workload instead of "
                         "independent prompts: every request starts with "
                         "one of --shared-prefixes fixed system prompts of "
                         "this many tokens (0 = off)")
    ap.add_argument("--shared-prefixes", type=int, default=2,
                    help="number of distinct system prompts in the "
                         "shared-prefix mixture")
    ap.add_argument("--shared-suffix-len", type=int, default=16,
                    help="user-suffix tokens appended to each shared "
                         "prefix (fixed: equal padded lengths are what "
                         "lets prefix blocks match); the --prompt-len-* "
                         "flags are ignored in shared-prefix mode")
    ap.add_argument("--unified-step", default="on", choices=["on", "off"],
                    help="fuse the packed chunked-prefill frontier and the "
                         "decode+sample step into ONE device dispatch per "
                         "engine step (needs --prefill-chunk > 0; 'off' "
                         "dispatches one chunk per cursor plus a decode "
                         "step — the pre-fusion path, kept for A/B runs)")
    ap.add_argument("--pad-side", default="left", choices=["left", "right"],
                    help="prompt-bucket padding side: 'right' keeps content "
                         "at the row start so variable-length suffixes of a "
                         "shared prefix land on the same cached block "
                         "boundaries (better --prefix-cache hit rates; "
                         "token streams differ from 'left' because RoPE "
                         "positions shift)")
    ap.add_argument("--speculative", default="off",
                    choices=["off", "lookup"],
                    help="speculative decoding: 'lookup' drafts each "
                         "request's next tokens from its own prompt + "
                         "generated history (prompt-lookup n-grams, no "
                         "draft model) and verifies the whole window in "
                         "ONE batched dispatch — token streams stay "
                         "byte-identical to 'off'; only the tokens-per-"
                         "dispatch economics change")
    ap.add_argument("--spec-tokens", type=int, default=4,
                    help="max draft tokens per verify window with "
                         "--speculative lookup (the window scores "
                         "k + 1 positions; see docs/tuning.md for "
                         "choosing k)")
    ap.add_argument("--lookup-friendly", action="store_true",
                    help="generate the self-similar workload speculation "
                         "thrives on (each prompt is one motif tiled; "
                         "generation keeps cycling it, so prompt-lookup "
                         "drafts verify at accept rates near 1)")
    ap.add_argument("--motif-len", type=int, default=8,
                    help="motif tokens per --lookup-friendly prompt")
    ap.add_argument("--motif-repeats", type=int, default=4,
                    help="times each --lookup-friendly motif is tiled")
    ap.add_argument("--bursty", action="store_true",
                    help="generate the bursty overcommit workload "
                         "(waves of simultaneous arrivals) instead of "
                         "Poisson traffic — the scenario --preemption "
                         "recompute exists for")
    ap.add_argument("--burst-size", type=int, default=4,
                    help="requests per wave of the --bursty trace")
    ap.add_argument("--burst-gap", type=float, default=0.25,
                    help="seconds between --bursty waves")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel devices: shard heads/FFN over a "
                         "(tp,) mesh inside the fused engine step, with "
                         "per-device KV shards and per-device power "
                         "monitors (token streams stay byte-identical to "
                         "--tp 1; on CPU force a multi-device host with "
                         "XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=N)")
    args = ap.parse_args(argv)
    if args.prefix_cache and args.cache_layout != "paged":
        ap.error("--prefix-cache requires --cache-layout paged")
    if args.preemption != "off" and args.cache_layout != "paged":
        ap.error("--preemption recompute requires --cache-layout paged")
    if args.kv_num_blocks != "auto":
        try:
            args.kv_num_blocks = int(args.kv_num_blocks)
        except ValueError:
            ap.error("--kv-num-blocks takes an integer or 'auto'")

    cfg = get_config(args.arch, smoke=args.smoke)
    plo = max(int(args.prompt_len_mean // 4), 1)
    phi = max(int(args.prompt_len_mean * 2), plo + 1)
    spec = WorkloadSpec(
        arrival_rate=args.arrival_rate,
        num_requests=args.requests,
        prompt_len=LengthDist(kind=args.prompt_len_dist,
                              mean=args.prompt_len_mean, low=plo, high=phi),
        output_len=LengthDist(kind="fixed", mean=args.max_new,
                              low=1, high=max(args.max_new, 1)),
        temperature=args.temperature,
        seed=args.seed,
    )
    if args.replay:
        try:
            schedule = _parse_replay(args.replay)
        except ValueError as e:
            ap.error(str(e))
        arrivals = replay_trace(schedule, cfg.vocab_size,
                                seed=args.seed,
                                temperature=args.temperature, top_k=20)
    elif args.bursty:
        bursts = max(-(-args.requests // max(args.burst_size, 1)), 1)
        arrivals = bursty_trace(
            cfg.vocab_size, bursts=bursts, burst_size=args.burst_size,
            gap_s=args.burst_gap,
            prompt_len=max(int(args.prompt_len_mean), 1),
            max_new=args.max_new, seed=args.seed,
            temperature=args.temperature, top_k=20)[:args.requests]
    elif args.lookup_friendly:
        arrivals = lookup_friendly_trace(
            cfg.vocab_size, num_requests=args.requests,
            motif_len=args.motif_len, repeats=args.motif_repeats,
            max_new=args.max_new, arrival_rate=args.arrival_rate,
            seed=args.seed, temperature=args.temperature, top_k=20)
    elif args.shared_prefix_len > 0:
        arrivals = shared_prefix_trace(
            cfg.vocab_size, num_requests=args.requests,
            shared_prefix_len=args.shared_prefix_len,
            num_prefixes=args.shared_prefixes,
            suffix_len=args.shared_suffix_len,
            max_new=args.max_new, arrival_rate=args.arrival_rate,
            seed=args.seed, temperature=args.temperature, top_k=20)
    else:
        arrivals = poisson_trace(spec, cfg.vocab_size)

    kv_num_blocks = args.kv_num_blocks
    if kv_num_blocks == "auto":
        if args.cache_layout != "paged":
            ap.error("--kv-num-blocks auto requires --cache-layout paged")
        seq_lens = [len(a.prompt) + a.params.max_new_tokens
                    for a in arrivals]
        kv_num_blocks = cache_lib.suggest_num_blocks(
            seq_lens, args.kv_block_size, args.max_len, args.max_batch,
            concurrency=estimate_concurrency(arrivals, args.max_batch))
        worst = cache_lib.default_num_blocks(
            args.max_batch, args.max_len, args.kv_block_size)
        print(f"# --kv-num-blocks auto -> {kv_num_blocks} blocks "
              f"(worst case {worst}); pair with --preemption recompute "
              f"to survive a bursty tail")

    monitor = _make_monitor(args.power_reader, args.tp)
    # --tp > 1: the engine owns its (tp,) mesh (entered around every
    # trace/dispatch), so the ambient host data-mesh stays out of the way
    tp_mesh = make_tp_mesh(args.tp) if args.tp > 1 else None
    with rules.use_mesh(make_host_mesh() if tp_mesh is None else None):
        params, param_axes = model_lib.init(cfg, jax.random.PRNGKey(args.seed))
        engine = ServingEngine(cfg, params, max_batch=args.max_batch,
                               max_len=args.max_len, seed=args.seed,
                               mesh=tp_mesh,
                               param_axes=(param_axes if tp_mesh is not None
                                           else None),
                               cache_layout=args.cache_layout,
                               kv_block_size=args.kv_block_size,
                               kv_num_blocks=kv_num_blocks,
                               prefill_chunk=args.prefill_chunk,
                               prefill_budget=args.prefill_budget,
                               prefix_cache=args.prefix_cache,
                               preemption=args.preemption,
                               unified_step=args.unified_step == "on",
                               pad_side=args.pad_side,
                               speculative=args.speculative,
                               spec_tokens=args.spec_tokens)
        if args.http_port:
            from repro.serving.server import start_http_server

            if monitor is not None:
                engine.attach_monitor(monitor)
                monitor.__enter__()
            handle = start_http_server(engine, host=args.http_host,
                                       port=args.http_port,
                                       model_name=cfg.name)
            print(f"# serving {cfg.name} at {handle.url} "
                  f"(POST /v1/completions; Ctrl-C to stop)")
            try:
                while True:
                    time.sleep(1.0)
            except KeyboardInterrupt:
                pass
            handle.close()
            if monitor is not None:
                monitor.__exit__(None, None, None)
            summary = handle.server.summary()
            print(json.dumps(summary, indent=2, default=float))
            print("\n## Latency percentiles\n")
            print(report.to_markdown(report.serving_summary_rows(summary)))
            return 0
        driver = OpenLoopDriver(engine, arrivals)
        if monitor is not None:
            engine.attach_monitor(monitor)
            with monitor:
                finished = driver.run()
        else:
            finished = driver.run()

        summary = engine.latency_summary()
        print(json.dumps(summary, indent=2))
        print("\n## Latency percentiles\n")
        print(report.to_markdown(report.serving_summary_rows(summary)))
        throughput = report.serving_throughput_rows(summary)
        if throughput:
            print("\n## Step economics\n")
            print(report.to_markdown(throughput))
        print("\n## Per-request (energy attributed per token window)\n")
        print(report.to_markdown(report.serving_request_rows(
            sorted(finished, key=lambda r: r.uid))))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
