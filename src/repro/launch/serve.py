"""Production serving driver: batched engine + ELANA request metrics.

    python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
        --requests 8 --max-new 16 --max-batch 4
"""

from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.models import model as model_lib
from repro.serving.engine import ServingEngine
from repro.serving.sampling import SamplingParams
from repro.sharding import rules


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    with rules.use_mesh(make_host_mesh()):
        params, _ = model_lib.init(cfg, jax.random.PRNGKey(args.seed))
        engine = ServingEngine(cfg, params, max_batch=args.max_batch,
                               max_len=args.max_len)
        rng = np.random.default_rng(args.seed)
        for i in range(args.requests):
            plen = int(rng.integers(4, args.max_len // 4))
            prompt = rng.integers(0, cfg.vocab_size, plen)
            engine.submit(prompt, SamplingParams(
                temperature=args.temperature, top_k=20,
                max_new_tokens=args.max_new))
        finished = engine.run()
        summary = engine.latency_summary()
        summary["tokens_generated"] = sum(len(r.output_tokens) for r in finished)
        print(json.dumps(summary, indent=2))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
