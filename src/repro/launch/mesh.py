"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.  Production target: TPU v5e pods — a 16x16
(256-chip) pod with axes (data, model), or 2 pods = 512 chips with a
leading `pod` axis that composes with `data` for cross-pod data parallelism
(gradient all-reduce crosses the pod axis; model parallelism never does).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist, as a 1D (data,) mesh — CPU smoke runs."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))
