"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.  Production target: TPU v5e pods — a 16x16
(256-chip) pod with axes (data, model), or 2 pods = 512 chips with a
leading `pod` axis that composes with `data` for cross-pod data parallelism
(gradient all-reduce crosses the pod axis; model parallelism never does).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist, as a 1D (data,) mesh — CPU smoke runs."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))


def make_tp_mesh(tp: int):
    """First ``tp`` local devices as a 1D (tp,) tensor-parallel mesh.

    The serving engine shards heads/FFN over this axis (rules.TP_SERVE_RULES)
    while slot state stays replicated.  On CPU, force a multi-device host
    with XLA_FLAGS=--xla_force_host_platform_device_count=N before importing
    jax.
    """
    import numpy as np
    from jax.sharding import Mesh

    devices = jax.devices()
    if tp < 1:
        raise ValueError(f"--tp must be >= 1, got {tp}")
    if tp > len(devices):
        raise ValueError(
            f"--tp {tp} exceeds the {len(devices)} visible device(s); on CPU "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=N")
    return Mesh(np.asarray(devices[:tp]), ("tp",))
