import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (architecture x input-shape x mesh) cell
lowers, SPMD-partitions, compiles, and fits — and extract the roofline
terms from the compiled artifact.

Per cell this produces (dumped to ``benchmarks/dryrun_results/*.json``):

  * compile proof + ``memory_analysis()`` (bytes per device),
  * ``cost_analysis()`` FLOPs/bytes of the compiled (scan-form) program,
  * collective inventory + bytes parsed from the post-SPMD HLO text,
  * **trip-count-corrected** totals: XLA's cost analysis visits a ``while``
    body once, so the scan-over-layer-groups undercounts by ~G.  We lower an
    *unrolled* variant (no mesh, global program) for exact FLOPs, and
    compile a one-group probe under the same shardings to correct bytes and
    collective bytes: total = full + (G-1) x group.
  * the three roofline terms vs the assignment's v5e constants.

Usage:
  python -m repro.launch.dryrun --arch minitron-4b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--shapes train_4k,...]
"""

import argparse
import json
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES, get_config, list_archs
from repro.core import hlo as hlo_lib
from repro.core import size as size_prof
from repro.kernels import dispatch
from repro.launch.mesh import make_production_mesh
from repro.models import flags
from repro.models import model as model_lib
from repro.models.config import ModelConfig, ShapeConfig
from repro.sharding import partition, rules
from repro.training.optimizer import AdamW, constant_schedule
from repro.training import step as step_lib

# assignment hardware constants (TPU v5e)
PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

# gradient-accumulation splits for train_4k (global batch 256); chosen so the
# per-microbatch activation live-set fits 16 GB/chip HBM (validated by the
# memory_analysis in each cell's JSON)
TRAIN_MICROBATCHES = {
    "default": 8,
    "command-r-plus-104b": 16,
    "llava-next-34b": 16,
    "minitron-4b": 8,
    "seamless-m4t-large-v2": 8,
}

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "dryrun_results")


# ---------------------------------------------------------------------------
# input specs
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct

    if shape.kind in ("train", "prefill"):
        tok_len = S
        batch = {}
        if cfg.num_vision_tokens:
            tok_len = S - cfg.num_vision_tokens
            batch["vision_embeds"] = sds((B, cfg.num_vision_tokens, cfg.d_model), dt)
        if cfg.is_encdec:
            tok_len = S // 2
            batch["enc_embeds"] = sds((B, S // 2, cfg.d_model), dt)
        batch["tokens"] = sds((B, tok_len), i32)
        if shape.kind == "train":
            batch["labels"] = sds((B, tok_len), i32)
        return batch

    if shape.kind == "decode":
        cache = jax.eval_shape(lambda: model_lib.init_cache(cfg, B, S, dt))
        return {
            "token": sds((B, 1), i32),
            "positions": sds((B,), i32),
            "cache": cache,
        }
    raise ValueError(shape.kind)


def should_skip(cfg: ModelConfig, shape: ShapeConfig) -> Optional[str]:
    if shape.name == "long_500k" and not cfg.subquadratic:
        return ("full-attention architecture: 500k-token decode requires "
                "sub-quadratic attention (DESIGN.md §4)")
    return None


# ---------------------------------------------------------------------------
# step builders with shardings
# ---------------------------------------------------------------------------

def _build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, opts=frozenset()):
    """Returns (jitted_fn, arg_specs: tuple) for lower()."""
    param_shapes, axes = model_lib.param_axes(cfg)
    param_sh = partition.param_shardings(axes, param_shapes, mesh)

    if shape.kind == "train":
        opt = AdamW(schedule=constant_schedule(1e-4))
        state_shapes = jax.eval_shape(
            lambda: step_lib.TrainState(
                params=param_shapes,
                opt=opt.init(param_shapes),
                step=jnp.zeros((), jnp.int32),
            )
        )
        state_sh = step_lib.TrainState(
            params=param_sh,
            opt=type(state_shapes.opt)(
                mu=param_sh, nu=param_sh, count=partition.replicated(mesh)),
            step=partition.replicated(mesh),
        )
        batch_shapes = input_specs(cfg, shape)
        batch_sh = partition.batch_shardings(batch_shapes, mesh)
        fn = step_lib.make_train_step(
            cfg, opt, remat=True, microbatches=shape.microbatches,
            param_pspecs=param_sh if "shard_grads" in opts else None)
        jitted = jax.jit(fn, in_shardings=(state_sh, batch_sh),
                         donate_argnums=(0,))
        return jitted, (state_shapes, batch_shapes)

    if shape.kind == "prefill":
        batch_shapes = input_specs(cfg, shape)
        batch_sh = partition.batch_shardings(batch_shapes, mesh)
        cache_shapes = jax.eval_shape(
            lambda: model_lib.init_cache(cfg, shape.global_batch, shape.seq_len,
                                         jnp.dtype(cfg.dtype)))
        cache_sh = partition.cache_shardings(cache_shapes, mesh)
        fn = lambda p, b, c: model_lib.prefill(cfg, p, b, c)
        jitted = jax.jit(fn, in_shardings=(param_sh, batch_sh, cache_sh),
                         donate_argnums=(2,))
        return jitted, (param_shapes, batch_shapes, cache_shapes)

    # decode / serve_step
    specs = input_specs(cfg, shape)
    cache_sh = partition.cache_shardings(specs["cache"], mesh)
    tok_sh = partition.batch_shardings(specs["token"], mesh)
    pos_sh = partition.batch_shardings(specs["positions"], mesh)
    fn = lambda p, t, pos, c: model_lib.decode_step(cfg, p, t, pos, c)
    jitted = jax.jit(fn, in_shardings=(param_sh, tok_sh, pos_sh, cache_sh),
                     donate_argnums=(3,))
    return jitted, (param_shapes, specs["token"], specs["positions"],
                    specs["cache"])


# ---------------------------------------------------------------------------
# group probe (bytes / collective correction)
# ---------------------------------------------------------------------------

def _build_group_probe(cfg: ModelConfig, shape: ShapeConfig, mesh,
                       opts=frozenset()):
    """One scan-group application under cell shardings; None if no groups."""
    n_groups, _ = cfg.layer_groups()
    if n_groups <= 1:
        return None
    param_shapes, axes = model_lib.param_axes(cfg)
    if "groups" not in param_shapes.get("decoder", {}):
        return None
    g_shapes = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype),
        param_shapes["decoder"]["groups"])
    g_axes = jax.tree.map(
        lambda ax: tuple(ax[1:]),
        axes["decoder"]["groups"],
        is_leaf=lambda l: isinstance(l, tuple) and all(
            isinstance(a, (str, type(None))) for a in l))
    g_sh = partition.param_shardings(g_axes, g_shapes, mesh)

    B, S = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    mb = B // shape.microbatches if shape.kind == "train" else B
    pattern = cfg.block_pattern
    memory = None
    mem_sh = None
    if cfg.is_encdec:
        memory = jax.ShapeDtypeStruct((mb, S // 2, cfg.d_model), dt)
        mem_sh = partition.batch_shardings(memory, mesh)

    if shape.kind in ("train", "prefill"):
        seq = S if shape.kind == "prefill" else (
            S - cfg.num_vision_tokens if cfg.num_vision_tokens else
            (S // 2 if cfg.is_encdec else S))
        if cfg.num_vision_tokens:
            seq = S  # vision prefix is part of the decoder sequence
        x_spec = jax.ShapeDtypeStruct((mb, seq, cfg.d_model), dt)
        x_sh = partition.batch_shardings(x_spec, mesh)

        def group_fwd(x, gparams, memory=None):
            positions = jnp.broadcast_to(
                jnp.arange(x.shape[1], dtype=jnp.int32)[None], x.shape[:2])
            for i, kind in enumerate(pattern):
                x, _ = model_lib._apply_block_seq(
                    gparams[str(i)], cfg, kind, x, positions, None, memory,
                    causal=True, fill_cache=False)
            return x

        if shape.kind == "train":
            def probe(x, gparams, memory=None):
                def loss(gp):
                    out = group_fwd(x, gp, memory)
                    return jnp.sum(out.astype(jnp.float32) ** 2)
                val, grads = jax.value_and_grad(loss)(gparams)
                if "shard_grads" in opts:
                    grads = jax.tree.map(
                        lambda g, s: jax.lax.with_sharding_constraint(g, s),
                        grads, g_sh)
                return val, grads
        else:
            probe = group_fwd
        args = (x_spec, g_shapes) + ((memory,) if cfg.is_encdec else ())
        shs = (x_sh, g_sh) + ((mem_sh,) if cfg.is_encdec else ())
        return jax.jit(probe, in_shardings=shs), args

    # decode probe: one group of _apply_block_decode
    cache_shapes = jax.eval_shape(
        lambda: model_lib.init_cache(cfg, B, S, dt))
    g_cache = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype),
        cache_shapes["groups"])
    g_cache_sh = partition.cache_shardings(
        {"rest": g_cache}, mesh)["rest"]  # batch at axis 0 after stripping
    x_spec = jax.ShapeDtypeStruct((B, 1, cfg.d_model), dt)
    pos_spec = jax.ShapeDtypeStruct((B,), jnp.int32)
    if "serve_repl" in opts:
        # weight-stationary serving replicates decode activations
        x_sh = partition.replicated(mesh)
        pos_sh = partition.replicated(mesh)
    else:
        x_sh = partition.batch_shardings(x_spec, mesh)
        pos_sh = partition.batch_shardings(pos_spec, mesh)

    def probe(x, gparams, gcache, positions):
        nc = {}
        for i, kind in enumerate(pattern):
            x, nc[str(i)] = model_lib._apply_block_decode(
                gparams[str(i)], cfg, kind, x, positions, gcache[str(i)])
        return x, nc

    return (jax.jit(probe, in_shardings=(x_sh, g_sh, g_cache_sh, pos_sh),
                    donate_argnums=(2,)),
            (x_spec, g_shapes, g_cache, pos_spec))


def _build_micro_probe(cfg: ModelConfig, shape: ShapeConfig, mesh,
                       opts=frozenset()):
    """One microbatch fwd+bwd (embed + group-scan-once + unembed + loss)."""
    import dataclasses as _dc

    param_shapes, axes = model_lib.param_axes(cfg)
    param_sh = partition.param_shardings(axes, param_shapes, mesh)
    micro_shape = _dc.replace(shape, microbatches=1,
                              global_batch=shape.global_batch // shape.microbatches)
    batch_shapes = input_specs(cfg, micro_shape)
    batch_sh = partition.batch_shardings(batch_shapes, mesh)
    loss_fn = step_lib.make_loss_fn(cfg, remat=True)

    def probe(params, batch):
        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        if "shard_grads" in opts:
            grads = jax.tree.map(
                lambda g, s: jax.lax.with_sharding_constraint(g, s),
                grads, param_sh)
        return loss, grads

    return (jax.jit(probe, in_shardings=(param_sh, batch_sh)),
            (param_shapes, batch_shapes))


# ---------------------------------------------------------------------------
# per-cell run
# ---------------------------------------------------------------------------

def _unrolled_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Exact global HLO FLOPs: unrolled lowering, no mesh, no compile."""
    specs = input_specs(cfg, shape)
    with flags.use_unroll():
        if shape.kind == "train":
            opt = AdamW(schedule=constant_schedule(1e-4))
            state_shapes = jax.eval_shape(
                lambda: step_lib.TrainState(
                    params=model_lib.param_axes(cfg)[0],
                    opt=opt.init(model_lib.param_axes(cfg)[0]),
                    step=jnp.zeros((), jnp.int32)))
            fn = step_lib.make_train_step(cfg, opt, remat=True,
                                          microbatches=shape.microbatches)
            lowered = jax.jit(fn).lower(state_shapes, specs)
        elif shape.kind == "prefill":
            params = model_lib.param_axes(cfg)[0]
            cache = jax.eval_shape(lambda: model_lib.init_cache(
                cfg, shape.global_batch, shape.seq_len, jnp.dtype(cfg.dtype)))
            lowered = jax.jit(
                lambda p, b, c: model_lib.prefill(cfg, p, b, c)
            ).lower(params, specs, cache)
        else:
            params = model_lib.param_axes(cfg)[0]
            lowered = jax.jit(
                lambda p, t, pos, c: model_lib.decode_step(cfg, p, t, pos, c)
            ).lower(params, specs["token"], specs["positions"], specs["cache"])
    cost = lowered.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    return float(cost.get("flops", 0.0))


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """6·N_active·D (train) / 2·N_active·D (inference)."""
    rep = size_prof.profile_size(cfg)
    n = rep.active_param_count
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        if cfg.is_encdec:
            tokens = shape.global_batch * shape.seq_len  # enc+dec halves
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # one decoded token per sequence


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             skip_unroll: bool = False, opts=frozenset()) -> Dict:
    import dataclasses as _dc

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        mb = TRAIN_MICROBATCHES.get(arch, TRAIN_MICROBATCHES["default"])
        # per-microbatch batch must stay divisible by the data-parallel size
        dp = 32 if multi_pod else 16
        mb = min(mb, max(shape.global_batch // dp, 1))
        shape = _dc.replace(shape, microbatches=mb)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    result: Dict = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(str(mesh.shape[a]) for a in mesh.axis_names),
        "chips": chips, "kind": shape.kind,
    }
    skip = should_skip(cfg, shape)
    if skip:
        result["status"] = "skipped"
        result["reason"] = skip
        return result

    result["opts"] = sorted(opts)
    dispatch.set_backend("xla")  # cost analysis needs real HLO
    cell_rules = None
    if "serve_repl" in opts and shape.kind == "decode":
        cell_rules = rules.SERVE_RULES  # weight-stationary decode
    t0 = time.time()
    import contextlib as _ctx
    moe_ctx = (flags.use_moe_blocked() if "moe_block" in opts
               else _ctx.nullcontext())
    with rules.use_mesh(mesh, cell_rules), moe_ctx:
        jitted, arg_shapes = _build_cell(cfg, shape, mesh, opts)
        lowered = jitted.lower(*arg_shapes)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
        text = compiled.as_text()
        summary = hlo_lib.summarize_compiled(compiled, text)
        mem = compiled.memory_analysis()

        # trip-count correction probes.  Post-SPMD cost numbers are
        # per-device (the compiled module is the per-partition program):
        #   real = full + (M-1) x micro + M x (G-1) x group   (train)
        #   real = full + (G-1) x group                       (prefill/decode)
        n_groups, _ = cfg.layer_groups()
        M = shape.microbatches
        flops_c = summary.flops
        bytes_c = summary.bytes_accessed
        coll_c = summary.collectives.total_bytes
        if n_groups > 1:
            probe = _build_group_probe(cfg, shape, mesh, opts)
            if probe is not None:
                pfn, pargs = probe
                pcompiled = pfn.lower(*pargs).compile()
                psum = hlo_lib.summarize_compiled(pcompiled, pcompiled.as_text())
                g_reps = M * (n_groups - 1)
                flops_c += g_reps * psum.flops
                bytes_c += g_reps * psum.bytes_accessed
                coll_c += g_reps * psum.collectives.total_bytes
        if shape.kind == "train" and M > 1:
            mfn, margs = _build_micro_probe(cfg, shape, mesh, opts)
            mcompiled = mfn.lower(*margs).compile()
            msum = hlo_lib.summarize_compiled(mcompiled, mcompiled.as_text())
            # subtract the group scan counted once inside the micro probe —
            # it is already covered by the group correction above
            flops_c += (M - 1) * msum.flops
            bytes_c += (M - 1) * msum.bytes_accessed
            coll_c += (M - 1) * msum.collectives.total_bytes

    flops_unrolled = None
    if not skip_unroll:
        try:
            flops_unrolled = _unrolled_flops(cfg, shape)  # GLOBAL flops
        except Exception as e:  # very large unrolls: fall back to correction
            result["unroll_error"] = repr(e)

    # corrected per-device totals -> the roofline terms are per-chip seconds
    flops_global = flops_unrolled if flops_unrolled else flops_c * chips

    mf = model_flops(cfg, shape)
    compute_term = flops_global / (chips * PEAK_FLOPS)
    memory_term = bytes_c / HBM_BW
    coll_term = coll_c / LINK_BW
    dominant = max(
        (("compute", compute_term), ("memory", memory_term),
         ("collective", coll_term)), key=lambda kv: kv[1])[0]

    def _mem(attr):
        return int(getattr(mem, attr, 0) or 0)

    result.update({
        "status": "ok",
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes_per_device": _mem("argument_size_in_bytes"),
            "output_bytes_per_device": _mem("output_size_in_bytes"),
            "temp_bytes_per_device": _mem("temp_size_in_bytes"),
            "peak_bytes_estimate": _mem("argument_size_in_bytes")
            + _mem("temp_size_in_bytes"),
        },
        "cost": {
            "flops_perdev_compiled_once": summary.flops,
            "flops_unrolled_global": flops_unrolled,
            "flops_global": flops_global,
            "flops_perdev_corrected": flops_c,
            "bytes_perdev_compiled_once": summary.bytes_accessed,
            "bytes_perdev_corrected": bytes_c,
            "microbatches": shape.microbatches,
        },
        "collectives": {
            "counts": summary.collectives.counts,
            "bytes_by_kind_perdev_once": summary.collectives.bytes_by_kind,
            "bytes_perdev_once": summary.collectives.total_bytes,
            "bytes_perdev_corrected": coll_c,
        },
        "roofline": {
            "compute_term_s": compute_term,
            "memory_term_s": memory_term,
            "collective_term_s": coll_term,
            "dominant": dominant,
            "model_flops": mf,
            "useful_flops_ratio": mf / max(flops_global, 1.0),
        },
    })
    return result


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--shapes", default=None, help="comma-separated")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--skip-unroll", action="store_true")
    ap.add_argument("--opt", default="", help="comma list: shard_grads,serve_repl")
    ap.add_argument("--out-dir", default=None)
    args = ap.parse_args()

    out_dir = args.out_dir or os.path.abspath(RESULTS_DIR)
    os.makedirs(out_dir, exist_ok=True)
    archs = list_archs()[:10] if args.all else [args.arch]
    shapes = (args.shapes.split(",") if args.shapes
              else (list(SHAPES) if (args.all or not args.shape)
                    else [args.shape]))

    opts = frozenset(x for x in args.opt.split(",") if x)
    failures = 0
    for arch in archs:
        for shape_name in shapes:
            tag = f"{arch}__{shape_name}__{'2x16x16' if args.multi_pod else '16x16'}"
            if opts:
                tag += "__opt-" + "-".join(sorted(opts))
            path = os.path.join(out_dir, tag + ".json")
            print(f"=== {tag} ===", flush=True)
            try:
                res = run_cell(arch, shape_name, args.multi_pod,
                               skip_unroll=args.skip_unroll, opts=opts)
            except Exception:
                failures += 1
                res = {"arch": arch, "shape": shape_name, "status": "error",
                       "traceback": traceback.format_exc()}
                print(res["traceback"], flush=True)
            with open(path, "w") as f:
                json.dump(res, f, indent=2)
            if res["status"] == "ok":
                r = res["roofline"]
                print(f"  compile {res['compile_s']}s | "
                      f"mem/dev {res['memory']['peak_bytes_estimate']/1e9:.2f} GB | "
                      f"terms c={r['compute_term_s']*1e3:.2f}ms "
                      f"m={r['memory_term_s']*1e3:.2f}ms "
                      f"coll={r['collective_term_s']*1e3:.2f}ms "
                      f"-> {r['dominant']} | useful={r['useful_flops_ratio']:.2f}",
                      flush=True)
            elif res["status"] == "skipped":
                print(f"  SKIP: {res['reason']}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
