"""Production training driver.

Wires every substrate together: config registry, mesh + sharding rules,
synthetic or token-file data with background prefetch, AdamW + grad-accum
train step, atomic checkpointing with sample-exact resume, preemption
handling, straggler watchdog, and optional ELANA energy monitoring of the
whole run.

    python -m repro.launch.train --arch tinyllama-1.1b --smoke \
        --steps 100 --batch 8 --seq-len 128 --ckpt-dir /tmp/run1

On a real pod, run one process per host with jax.distributed initialized;
the mesh comes from ``--mesh production`` (16x16) or ``--mesh host``
(whatever devices exist — the CPU dev rig).
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import energy as energy_lib
from repro.data.pipeline import Prefetcher
from repro.data.synthetic import SyntheticConfig, SyntheticDataset, batch_for_model
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import model as model_lib
from repro.sharding import partition, rules
from repro.training import checkpoint as ckpt_lib
from repro.training import step as step_lib
from repro.training.fault import PreemptionHandler, RunPosition, StragglerWatchdog
from repro.training.optimizer import AdamW, warmup_cosine_schedule


def build_argparser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", default="host", choices=["host", "production"])
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--energy", action="store_true",
                    help="sample power (ProcStat on CPU) during the run")
    ap.add_argument("--remat", action="store_true", default=False)
    return ap


def train(args) -> Dict[str, float]:
    cfg = get_config(args.arch, smoke=args.smoke)
    mesh = make_production_mesh() if args.mesh == "production" else make_host_mesh()
    opt = AdamW(schedule=warmup_cosine_schedule(args.lr, args.warmup, args.steps))

    with rules.use_mesh(mesh):
        state, axes = step_lib.init_state(cfg, opt, jax.random.PRNGKey(args.seed))
        param_sh = partition.param_shardings(
            axes, jax.tree.map(lambda x: x, state.params), mesh)
        train_step = jax.jit(
            step_lib.make_train_step(cfg, opt, remat=args.remat,
                                     microbatches=args.microbatches),
            donate_argnums=(0,),
        )

        ds = SyntheticDataset(SyntheticConfig(
            vocab_size=cfg.vocab_size, seq_len=args.seq_len,
            batch_size=args.batch, seed=args.seed))
        pos = RunPosition(step=0, data_epoch=0, data_offset=0, rng_seed=args.seed)

        # resume-from-latest (restart / elastic re-mesh path)
        if args.ckpt_dir and ckpt_lib.latest_step(args.ckpt_dir) is not None:
            tree = {"params": state.params, "opt_mu": state.opt.mu,
                    "opt_nu": state.opt.nu}
            restored, manifest = ckpt_lib.restore(args.ckpt_dir, tree)
            pos = RunPosition.from_metadata(manifest)
            from repro.training.optimizer import OptState
            state = step_lib.TrainState(
                params=restored["params"],
                opt=OptState(mu=restored["opt_mu"], nu=restored["opt_nu"],
                             count=jnp.asarray(pos.step, jnp.int32)),
                step=jnp.asarray(pos.step, jnp.int32))
            print(f"resumed from step {pos.step}")

        handler = PreemptionHandler().install()
        watchdog = StragglerWatchdog(threshold=3.0)
        monitor = None
        if args.energy:
            monitor = energy_lib.PowerMonitor(energy_lib.ProcStatReader())
            monitor.__enter__()

        rng = np.random.default_rng(args.seed)

        def batches():
            i = pos.step
            while True:
                yield i, batch_for_model(cfg, ds.batch_at(i), rng)
                i += 1

        it = Prefetcher(batches(), depth=2)
        losses = []
        t_start = time.perf_counter()
        final_step = pos.step
        for i, host_batch in it:
            if i >= args.steps or handler.preemption_requested:
                break
            batch = {k: jnp.asarray(v) for k, v in host_batch.items()}
            watchdog.start_step()
            state, metrics = train_step(state, batch)
            jax.block_until_ready(metrics["loss"])
            watchdog.end_step(i)
            losses.append(float(metrics["loss"]))
            final_step = i + 1
            if i % args.log_every == 0:
                print(f"step {i:5d} loss {losses[-1]:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"{watchdog.history[-1].seconds*1e3:.0f}ms", flush=True)
            if args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
                ckpt_lib.save(
                    args.ckpt_dir, i + 1,
                    {"params": state.params, "opt_mu": state.opt.mu,
                     "opt_nu": state.opt.nu},
                    metadata=RunPosition(step=i + 1, data_epoch=0,
                                         data_offset=i + 1,
                                         rng_seed=args.seed).to_metadata())
        it.close()

        # preemption / end-of-run checkpoint
        if args.ckpt_dir:
            ckpt_lib.save(
                args.ckpt_dir, final_step,
                {"params": state.params, "opt_mu": state.opt.mu,
                 "opt_nu": state.opt.nu},
                metadata=RunPosition(step=final_step, data_epoch=0,
                                     data_offset=final_step,
                                     rng_seed=args.seed).to_metadata())
        handler.uninstall()
        wall = time.perf_counter() - t_start

        out = {
            "steps": len(losses),
            "final_step": final_step,
            "loss_first": losses[0] if losses else float("nan"),
            "loss_last": losses[-1] if losses else float("nan"),
            "mean_step_ms": watchdog.mean_step_s * 1e3,
            "stragglers": watchdog.straggler_count,
            "wall_s": wall,
            "preempted": handler.preemption_requested,
        }
        if monitor is not None:
            monitor.__exit__(None, None, None)
            e = monitor.result()
            out["avg_watts"] = e.avg_watts
            out["joules"] = e.joules
            out["j_per_step"] = e.joules / max(len(losses), 1)
        return out


def main(argv=None) -> int:
    args = build_argparser().parse_args(argv)
    out = train(args)
    print(json.dumps(out, indent=2))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
