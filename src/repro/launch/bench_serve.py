"""Steady-state serving benchmark over HTTP.

Stands up the OpenAI-compatible server on an in-process engine, drives
it with the closed/open-loop load generator (warmup, then a fixed
steady-state window with the power monitor bracketing exactly that
window), and reports client-side latencies next to the engine's own —
plus the energy ledger, where the sum of per-request token-weighted
``joules_between`` windows must equal ``PowerMonitor.result().joules``
exactly under the step-function model.

    python -m repro.launch.bench_serve --arch qwen1.5-0.5b --smoke \
        --mode closed --concurrency 2 --warmup-s 1 --duration-s 3 \
        --max-new 8 --power-reader synthetic --check

``--check`` turns the measurement-protocol acceptance criteria into hard
assertions (non-zero exit on violation): steady-state requests were
measured, client TTFT/TPOT agree with engine-side within
``--ttft-tolerance-ms``, the energy ledger tiles exactly, and the
achieved power sample rate is at least half the configured target.
"""

from __future__ import annotations

import argparse
import json

import jax

from repro.configs import get_config
from repro.core import report
from repro.core.energy import (DeviceMonitorGroup, ModelReader, PowerMonitor,
                               ProcStatReader, SyntheticReader)
from repro.launch.mesh import make_host_mesh, make_tp_mesh
from repro.models import model as model_lib
from repro.serving.engine import ServingEngine
from repro.serving.loadgen import LoadSpec, prewarm_engine, run_load
from repro.serving.server import start_http_server
from repro.sharding import rules


def _make_reader(kind: str):
    if kind == "proc":
        return ProcStatReader()
    if kind == "model":
        return ModelReader(idle_watts=10.0, tdp_watts=65.0)
    if kind == "synthetic":
        import math

        return SyntheticReader(lambda t: 40.0 + 10.0 * math.sin(t * 7.0))
    return None


def _check(summary, args) -> None:
    """Measurement-protocol gates (ISSUE acceptance criteria)."""
    fails = []
    if summary["steady_requests"] < 1:
        fails.append("no requests completed inside the steady-state window "
                     "(increase --duration-s or lower --warmup-s)")
    d_ttft = summary["ttft_client_minus_engine_ms"]
    if not (-1.0 <= d_ttft <= args.ttft_tolerance_ms):
        fails.append(f"client-vs-engine TTFT delta {d_ttft:.1f} ms outside "
                     f"[-1, {args.ttft_tolerance_ms}] ms")
    d_tpot = summary["tpot_client_minus_engine_ms"]
    if abs(d_tpot) > args.ttft_tolerance_ms / 5.0:
        fails.append(f"client-vs-engine TPOT delta {d_tpot:.2f} ms beyond "
                     f"{args.ttft_tolerance_ms / 5.0:.0f} ms")
    if "joules_total" in summary:
        total = summary["joules_total"]
        attributed = summary["joules_attributed"]
        if abs(attributed - total) > 1e-9 * max(abs(total), 1.0):
            fails.append(f"energy ledger drift: per-request windows sum to "
                         f"{attributed!r} J but the run total is {total!r} J")
        min_rate = 0.5 / args.power_interval
        if summary["power_samples_per_sec"] < min_rate:
            fails.append(f"power sampler achieved "
                         f"{summary['power_samples_per_sec']:.1f} Hz, below "
                         f"{min_rate:.1f} Hz (half the configured target)")
    if fails:
        raise SystemExit("--check failed:\n  - " + "\n  - ".join(fails))
    print("# --check passed: steady-state protocol + energy ledger OK")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--prefill-chunk", type=int, default=0)
    ap.add_argument("--mode", default="closed", choices=["closed", "open"],
                    help="closed = concurrency-N workers (next request the "
                         "moment the previous finishes); open = Poisson "
                         "arrivals at --qps independent of completions")
    ap.add_argument("--concurrency", type=int, default=2,
                    help="closed-loop requests in flight")
    ap.add_argument("--qps", type=float, default=4.0,
                    help="open-loop mean arrival rate")
    ap.add_argument("--warmup-s", type=float, default=1.0,
                    help="unmeasured ramp (JIT compilation, cache fill) "
                         "before the steady-state window opens")
    ap.add_argument("--duration-s", type=float, default=5.0,
                    help="steady-state measurement window; only requests "
                         "sent inside it are counted")
    ap.add_argument("--max-requests", type=int, default=10_000)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--power-reader", default="synthetic",
                    choices=["proc", "model", "synthetic", "none"])
    ap.add_argument("--power-interval", type=float, default=0.1,
                    help="power sample interval in seconds (0.1 = the "
                         "paper's 10 Hz)")
    ap.add_argument("--check", action="store_true",
                    help="assert the measurement protocol held: client/"
                         "engine latency agreement, exact energy-ledger "
                         "tiling, achieved sampler rate")
    ap.add_argument("--ttft-tolerance-ms", type=float, default=250.0,
                    help="--check bound on mean client-minus-engine TTFT")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel devices: shard the served model "
                         "over a (tp,) mesh with one power monitor per "
                         "device (streams stay byte-identical to --tp 1; "
                         "on CPU force a multi-device host with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.power_reader == "none":
        monitor = None
    elif args.tp > 1:
        monitor = DeviceMonitorGroup(
            [_make_reader(args.power_reader) for _ in range(args.tp)],
            interval_s=args.power_interval)
    else:
        monitor = PowerMonitor(_make_reader(args.power_reader),
                               interval_s=args.power_interval)

    tp_mesh = make_tp_mesh(args.tp) if args.tp > 1 else None
    with rules.use_mesh(make_host_mesh() if tp_mesh is None else None):
        params, param_axes = model_lib.init(cfg, jax.random.PRNGKey(args.seed))
        engine = ServingEngine(cfg, params, max_batch=args.max_batch,
                               max_len=args.max_len, seed=args.seed,
                               mesh=tp_mesh,
                               param_axes=(param_axes if tp_mesh is not None
                                           else None),
                               prefill_chunk=args.prefill_chunk)
        if monitor is not None:
            engine.attach_monitor(monitor)
        prewarm_engine(engine, prompt_len=args.prompt_len,
                       concurrency=min(args.concurrency, args.max_batch),
                       vocab_size=cfg.vocab_size, seed=args.seed)
        handle = start_http_server(engine, model_name=cfg.name)
        spec = LoadSpec(mode=args.mode, concurrency=args.concurrency,
                        qps=args.qps, warmup_s=args.warmup_s,
                        duration_s=args.duration_s,
                        max_requests=args.max_requests,
                        prompt_len=args.prompt_len, max_new=args.max_new,
                        temperature=args.temperature,
                        vocab_size=cfg.vocab_size, seed=args.seed)
        print(f"# driving {handle.url} : mode={spec.mode} "
              f"warmup={spec.warmup_s}s window={spec.duration_s}s")
        try:
            result = run_load(handle.url, spec, monitor=monitor)
            engine_summary = handle.server.summary()
        finally:
            handle.close()

    summary = result.summary
    print(json.dumps(summary, indent=2, default=float))
    print("\n## Client-side steady state\n")
    print(report.to_markdown(report.serving_client_rows(summary)))
    print("\n## Engine-side (same run, via /metrics ledger)\n")
    print(report.to_markdown(report.serving_summary_rows(engine_summary)))
    if args.check:
        _check(summary, args)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
