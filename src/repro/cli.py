"""The ``elana`` command-line interface (paper §1: "run a command from the
terminal without modifying the code").

    elana archs
    elana size    --arch llama3.1-8b
    elana cache   --arch nemotron-h-8b --batch 128 --seq-len 2048
    elana latency --arch tinyllama-1.1b --smoke --batch 1 --prompt 64 --gen 16
    elana energy  --arch tinyllama-1.1b --smoke --batch 1 --prompt 64 --gen 16
    elana estimate --arch qwen2.5-7b --hardware a6000 --batch 1 --prompt 512 --gen 512
    elana trace   --arch llama3.1-8b --hardware tpu-v5e --out trace.json
    elana report  --hardware a6000
    elana dryrun  --arch minitron-4b --shape train_4k --multi-pod
"""

from __future__ import annotations

import argparse
import json
import sys


def _add_common(p, smoke_default=False):
    p.add_argument("--arch", required=True)
    p.add_argument("--smoke", action="store_true", default=smoke_default,
                   help="use the reduced (CPU-runnable) config variant")
    p.add_argument("--unit", default="GB", help="GB (SI, default) or GiB")


def cmd_archs(args) -> int:
    from repro.configs import ASSIGNED, PAPER

    print("assigned pool:")
    for a in ASSIGNED:
        print(f"  {a}")
    print("paper models:")
    for a in PAPER:
        print(f"  {a}")
    return 0


def cmd_size(args) -> int:
    from repro.core.profiler import Elana

    rep = Elana(args.arch, smoke=args.smoke).size_report()
    print(rep.fmt(args.unit))
    return 0


def cmd_cache(args) -> int:
    from repro.core.profiler import Elana

    rep = Elana(args.arch, smoke=args.smoke).cache_report(args.batch, args.seq_len)
    print(rep.fmt(args.unit))
    return 0


def cmd_latency(args) -> int:
    from repro.core.profiler import Elana

    out = Elana(args.arch, smoke=args.smoke).measure(
        batch=args.batch, prompt_len=args.prompt, gen_len=args.gen,
        iters=args.iters,
    )
    print(json.dumps(out, indent=2))
    return 0


def cmd_energy(args) -> int:
    from repro.core import energy as energy_lib
    from repro.core.hardware import get_hardware
    from repro.core.profiler import Elana

    hw = get_hardware(args.hardware)
    reader = energy_lib.ProcStatReader(hw.idle_watts, hw.tdp_watts) \
        if args.hardware == "cpu" else energy_lib.ModelReader(
            hw.idle_watts, hw.tdp_watts)
    out = Elana(args.arch, smoke=args.smoke).measure(
        batch=args.batch, prompt_len=args.prompt, gen_len=args.gen,
        iters=args.iters, power_reader=reader,
    )
    print(json.dumps(out, indent=2))
    return 0


def cmd_estimate(args) -> int:
    from repro.core import report
    from repro.core.profiler import Elana

    est = Elana(args.arch, smoke=args.smoke).estimate(
        hardware=args.hardware, n_devices=args.n_devices, mode=args.mode,
        batch=args.batch, prompt_len=args.prompt, gen_len=args.gen,
    )
    print(report.to_markdown(report.table3_rows([est])))
    for ph in (est.ttft, est.tpot):
        print(f"  {ph.name}: bound={ph.bound} compute={ph.compute_s*1e3:.2f}ms "
              f"memory={ph.memory_s*1e3:.2f}ms coll={ph.collective_s*1e3:.2f}ms "
              f"avg_watts={ph.avg_watts:.0f}")
    return 0


def cmd_trace(args) -> int:
    from repro.core.profiler import Elana

    summary = Elana(args.arch, smoke=args.smoke).trace(
        args.out, hardware=args.hardware, phase=args.phase,
        batch=args.batch, seq_len=args.seq_len,
    )
    print(f"wrote {args.out} (open at https://ui.perfetto.dev)")
    print(json.dumps(summary, indent=2))
    return 0


def cmd_report(args) -> int:
    from repro.core import report
    from repro.core.profiler import Elana
    from repro.configs import PAPER

    archs = args.archs.split(",") if args.archs else PAPER
    sizes, caches, ests = [], {}, []
    for a in archs:
        e = Elana(a)
        sizes.append(e.size_report())
        caches[e.cfg.name] = {
            (1, 1024): e.cache_report(1, 1024),
            (128, 1024): e.cache_report(128, 1024),
            (128, 2048): e.cache_report(128, 2048),
        }
        ests.append(e.estimate(hardware=args.hardware, batch=1,
                               prompt_len=512, gen_len=512))
    print("## Table 2: model + cache size")
    print(report.to_markdown(report.table2_rows(sizes, caches)))
    print()
    print(f"## Table 3-style: latency/energy on {args.hardware} (estimator)")
    print(report.to_markdown(report.table3_rows(ests)))
    return 0


def cmd_dryrun(args) -> int:
    # Heavy import chain + XLA_FLAGS env var: delegate to the launch module
    # in a fresh interpreter so device count forcing works.
    import subprocess

    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", args.arch,
           "--shape", args.shape]
    if args.multi_pod:
        cmd.append("--multi-pod")
    return subprocess.call(cmd)


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="elana",
        description="ELANA-JAX: energy & latency analyzer for LLMs (TPU-native)",
    )
    sub = ap.add_subparsers(dest="cmd", required=True)

    sub.add_parser("archs").set_defaults(fn=cmd_archs)

    p = sub.add_parser("size")
    _add_common(p)
    p.set_defaults(fn=cmd_size)

    p = sub.add_parser("cache")
    _add_common(p)
    p.add_argument("--batch", type=int, default=1)
    p.add_argument("--seq-len", type=int, default=1024)
    p.set_defaults(fn=cmd_cache)

    for name, fn in (("latency", cmd_latency), ("energy", cmd_energy)):
        p = sub.add_parser(name)
        _add_common(p)
        p.add_argument("--batch", type=int, default=1)
        p.add_argument("--prompt", type=int, default=64)
        p.add_argument("--gen", type=int, default=16)
        p.add_argument("--iters", type=int, default=5)
        p.add_argument("--hardware", default="cpu")
        p.set_defaults(fn=fn)

    p = sub.add_parser("estimate")
    _add_common(p)
    p.add_argument("--hardware", default="tpu-v5e")
    p.add_argument("--n-devices", type=int, default=1)
    p.add_argument("--mode", default="tp", choices=["tp", "dp", "naive_pp"])
    p.add_argument("--batch", type=int, default=1)
    p.add_argument("--prompt", type=int, default=512)
    p.add_argument("--gen", type=int, default=512)
    p.set_defaults(fn=cmd_estimate)

    p = sub.add_parser("trace")
    _add_common(p)
    p.add_argument("--hardware", default="tpu-v5e")
    p.add_argument("--phase", default="decode", choices=["decode", "prefill"])
    p.add_argument("--batch", type=int, default=1)
    p.add_argument("--seq-len", type=int, default=1024)
    p.add_argument("--out", default="elana_trace.json")
    p.set_defaults(fn=cmd_trace)

    p = sub.add_parser("report")
    p.add_argument("--archs", default="")
    p.add_argument("--hardware", default="a6000")
    p.set_defaults(fn=cmd_report)

    p = sub.add_parser("dryrun")
    p.add_argument("--arch", required=True)
    p.add_argument("--shape", default="train_4k")
    p.add_argument("--multi-pod", action="store_true")
    p.set_defaults(fn=cmd_dryrun)
    return ap


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
