"""KV / SSM / hybrid cache-size profiling (paper §2.2, Table 2).

Like ``core.size``, this evaluates the *real* decode-cache constructor under
``jax.eval_shape`` so the report reflects exactly what the runtime would
allocate for a (batch, seq_len) workload — attention KV, ring-buffered
sliding-window KV, recurrent matrix/scalar states, conv histories, and
cross-attention memory are all classified separately.  The paper's Table 2
reports attention-KV-dominated numbers; ``kv_bytes`` is the comparable
column and ``state_bytes`` is the SSM/recurrent extension.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from repro.core import units
from repro.models import model as model_lib
from repro.models.config import ModelConfig


@dataclasses.dataclass
class CacheReport:
    name: str
    batch: int
    seq_len: int
    total_bytes: int
    kv_bytes: int           # self-attention KV (full or windowed)
    state_bytes: int        # recurrent states (RG-LRU h, mLSTM C/n/m, conv)
    cross_bytes: int        # encoder-decoder cross-attention memory
    meta_bytes: int         # position bookkeeping
    by_kind: Dict[str, int]

    def fmt(self, unit: str = "GB") -> str:
        f = lambda b: units.fmt_bytes(b, unit)
        return (
            f"{self.name} cache @ batch={self.batch}, L={self.seq_len}: "
            f"total {f(self.total_bytes)} "
            f"(kv {f(self.kv_bytes)}, state {f(self.state_bytes)}, "
            f"cross {f(self.cross_bytes)})"
        )


def _classify(path) -> str:
    keys = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
    for k in keys:
        if k in ("cross_k", "cross_v"):
            return "cross"
        if k in ("pos", "ring", "block_tables"):
            return "meta"
    # inside a "self" attn entry -> kv; recurrent state names -> state
    if any(k == "self" for k in keys):
        return "kv"
    if keys[-1] in ("k", "v", "kp", "vp"):
        return "kv"
    return "state"


def profile_cache(
    cfg: ModelConfig, batch: int, seq_len: int, dtype=None,
    *, layout: str = "contiguous", block_size: int = 16, num_blocks: int = 0,
) -> CacheReport:
    dtype = dtype or jnp.dtype(cfg.dtype)
    tree = jax.eval_shape(
        lambda: model_lib.init_cache(cfg, batch, seq_len, dtype, layout=layout,
                                     block_size=block_size,
                                     num_blocks=num_blocks)
    )
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    by_kind: Dict[str, int] = {"kv": 0, "state": 0, "cross": 0, "meta": 0}
    for path, leaf in flat:
        nbytes = int(leaf.size) * jnp.dtype(leaf.dtype).itemsize
        by_kind[_classify(path)] += nbytes
    total = sum(by_kind.values())
    return CacheReport(
        name=cfg.name, batch=batch, seq_len=seq_len,
        total_bytes=total,
        kv_bytes=by_kind["kv"], state_bytes=by_kind["state"],
        cross_bytes=by_kind["cross"], meta_bytes=by_kind["meta"],
        by_kind=by_kind,
    )


def analytic_kv_bytes(cfg: ModelConfig, batch: int, seq_len: int,
                      itemsize: int = 2) -> int:
    """Closed-form attention-KV bytes — the cross-check oracle for tests
    and the formula the paper's Table 2 corresponds to."""
    total = 0
    for kind in cfg.blocks():
        if kind == "attn":
            length = seq_len
        elif kind == "local_attn":
            length = min(cfg.sliding_window, seq_len)
        else:
            continue
        total += 2 * batch * length * cfg.num_kv_heads * cfg.resolved_head_dim * itemsize
    return total


def paged_kv_bytes(cfg: ModelConfig, lengths, block_size: int,
                   itemsize: int = 2, max_len: int = 0) -> int:
    """Attention-KV bytes a paged cache *allocates* for per-request token
    counts ``lengths`` (prompt + generated): full-context layers consume
    ``ceil(len / block_size)`` pool blocks per request, while sliding-window
    layers keep their ring buffers — a fixed ``min(window, max_len)`` per
    resident request regardless of its length (paging does not change
    them).  The worst-case contiguous comparison point is
    ``analytic_kv_bytes(cfg, len(lengths), max_len)``."""
    max_len = max_len or max((int(n) for n in lengths), default=0)
    per_tok = 2 * cfg.num_kv_heads * cfg.resolved_head_dim * itemsize
    blocks = sum(-(-int(n) // block_size) for n in lengths)
    total = 0
    for kind in cfg.blocks():
        if kind == "attn":
            total += blocks * block_size * per_tok
        elif kind == "local_attn":
            total += len(lengths) * min(cfg.sliding_window, max_len) * per_tok
    return total
