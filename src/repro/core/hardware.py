"""Hardware spec registry for estimator-mode profiling and roofline analysis.

The paper measures on A6000 / Jetson AGX Thor / Orin Nano; the assignment
targets TPU v5e pods.  Peak numbers below are vendor-published; the TPU
constants are the ones fixed by the assignment (197 TFLOP/s bf16, 819 GB/s
HBM, ~50 GB/s/link ICI).  ``eta_*`` are achievable-fraction derates used by
the latency estimator (sustained / peak — published MLPerf-class systems
typically sustain 60-80% of peak HBM bandwidth and 40-70% of peak matmul
throughput at LLM shapes).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str
    kind: str                  # gpu | edge | tpu | cpu
    peak_flops_bf16: float     # FLOP/s per chip (bf16/fp16 tensor)
    hbm_bw: float              # bytes/s per chip
    link_bw: float             # bytes/s per inter-chip link (ICI / NVLink / PCIe)
    num_links: int             # links per chip contributing to collectives
    tdp_watts: float           # board power at full load
    idle_watts: float          # board power at idle
    mem_bytes: int             # HBM / unified memory per chip
    eta_compute: float = 0.6   # sustained fraction of peak FLOP/s
    eta_memory: float = 0.75   # sustained fraction of peak HBM BW
    eta_link: float = 0.8      # sustained fraction of peak link BW
    launch_overhead_s: float = 30e-6  # per-step dispatch overhead
    # power as seen by the paper's sensor. Jetson numbers come from the GPU
    # rail (jtop), which excludes DRAM/SoC power -> much lower than board TDP.
    rail_tdp_watts: float = 0.0   # 0 -> use tdp_watts
    rail_idle_watts: float = -1.0  # <0 -> use idle_watts

    def power_at(self, utilization: float) -> float:
        """Board power at a given utilization (linear idle->TDP model).

        This mirrors the paper's measurement method: they average sampled
        instantaneous power over the latency window; we model that average.
        """
        u = min(max(utilization, 0.0), 1.0)
        return self.idle_watts + (self.tdp_watts - self.idle_watts) * u


REGISTRY: Dict[str, HardwareSpec] = {}


def _reg(spec: HardwareSpec) -> HardwareSpec:
    REGISTRY[spec.name] = spec
    return spec


# --- the paper's platforms --------------------------------------------------

A6000 = _reg(HardwareSpec(
    # NVIDIA RTX A6000: 38.7 TF fp32 / 154.8 TF fp16 tensor (dense),
    # 768 GB/s GDDR6, 300 W board, NVLink3 112.5 GB/s (2 bricks).
    name="a6000", kind="gpu",
    peak_flops_bf16=154.8e12, hbm_bw=768e9,
    link_bw=56.25e9, num_links=2,
    tdp_watts=300.0, idle_watts=22.0, mem_bytes=48 * 1000**3,
    eta_compute=0.65, eta_memory=0.85,  # calibrated on paper Table 3 rows
))

JETSON_ORIN_NANO = _reg(HardwareSpec(
    # Orin Nano 8GB: 40 INT8 sparse TOPS ≈ 10 TF fp16 dense, 68 GB/s LPDDR5,
    # 15 W module (7-15 W envelope), unified memory.
    name="jetson-orin-nano", kind="edge",
    peak_flops_bf16=10e12, hbm_bw=68e9,
    link_bw=0.0, num_links=0,
    tdp_watts=15.0, idle_watts=4.0, mem_bytes=8 * 1000**3,
    eta_compute=0.45, eta_memory=0.75,   # calibrated on paper Table 4
    rail_tdp_watts=5.5, rail_idle_watts=0.1,
))

JETSON_AGX_THOR = _reg(HardwareSpec(
    # AGX Thor 128GB devkit: 1 PFLOP fp8 *sparse* -> ~250 TF fp16 dense
    # (Blackwell), 273 GB/s LPDDR5X, 40-130 W envelope.  eta calibrated on
    # paper Table 4 (power-capped devkit sustains ~22% of dense peak).
    name="jetson-agx-thor", kind="edge",
    peak_flops_bf16=250e12, hbm_bw=273e9,
    link_bw=0.0, num_links=0,
    tdp_watts=130.0, idle_watts=15.0, mem_bytes=128 * 1000**3,
    eta_compute=0.22, eta_memory=0.60,
    rail_tdp_watts=78.0, rail_idle_watts=1.0,
))

# --- the assignment's target ------------------------------------------------

TPU_V5E = _reg(HardwareSpec(
    # Assignment constants: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
    # v5e: 16 GB HBM2, ~2D torus with 4 ICI links/chip. Power: ~200 W-class
    # accelerator envelope (Google reports v5e at roughly half v4's ~192 W
    # measured average; we use 170 W board TDP, 60 W idle).
    name="tpu-v5e", kind="tpu",
    peak_flops_bf16=197e12, hbm_bw=819e9,
    link_bw=50e9, num_links=4,
    tdp_watts=170.0, idle_watts=60.0, mem_bytes=16 * 1000**3,
))

CPU_DEV = _reg(HardwareSpec(
    # The CPU dev container (measured-mode sanity runs only).
    name="cpu", kind="cpu",
    peak_flops_bf16=0.2e12, hbm_bw=20e9,
    link_bw=0.0, num_links=0,
    tdp_watts=65.0, idle_watts=10.0, mem_bytes=32 * 1000**3,
    eta_compute=0.5, eta_memory=0.5,
))


def get_hardware(name: str) -> HardwareSpec:
    if name not in REGISTRY:
        raise KeyError(f"unknown hardware {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]
