"""Analytic (estimator-mode) latency + energy model.

The dev container has no A6000/Jetson/TPU, so the paper's Tables 3-4 are
reproduced with a roofline-style analytic model over the hardware registry:

    t_phase = max(FLOPs / (chips · peak · η_c),  bytes / (chips · bw · η_m),
                  collective_bytes / (links · link_bw · η_l)) + overhead

Workload terms (FLOPs / bytes per phase) are derived from the model config +
the *real* size/cache profilers, so MoE activation fractions, sliding-window
caps, and recurrent state sizes are all accounted.

Energy follows the paper's method in model form: average power over the
phase window × latency.  Power = idle + (tdp−idle)·η_p·u, where the
utilization ``u`` depends on platform kind:

* server GPU / TPU: u = 1 when any roofline term saturates (boards pull
  near-TDP whether compute- or bandwidth-bound; calibrated η_p=0.91 against
  the paper's A6000 rows, which show ~275 W for both phases),
* edge (Jetson): the paper reads the GPU *rail*, which barely sees DRAM
  power → u = 0.7·compute_frac + 0.3·memory_frac (calibrated on Table 4).

Multi-device modes:
* ``tp``        — tensor parallel: FLOPs/bytes ÷ n, 2 all-reduces/layer.
* ``dp``        — data parallel inference: batch ÷ n, no collectives.
* ``naive_pp``  — HF accelerate-style sequential layer placement (what the
  paper's multi-GPU rows exhibit: one GPU busy at a time, others idle).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

from repro.core import cache as cache_prof
from repro.core import size as size_prof
from repro.core.hardware import HardwareSpec, get_hardware
from repro.models.config import ModelConfig

ETA_POWER = 0.91  # calibrated on paper Table 3 (A6000 ~275 W @ 300 W TDP)


@dataclasses.dataclass
class PhaseEstimate:
    name: str
    latency_s: float
    compute_s: float
    memory_s: float
    collective_s: float
    bound: str
    avg_watts: float
    joules: float
    flops: float
    bytes_moved: float


@dataclasses.dataclass
class WorkloadEstimate:
    arch: str
    hardware: str
    n_devices: int
    mode: str
    batch: int
    prompt_len: int
    gen_len: int
    ttft: PhaseEstimate
    tpot: PhaseEstimate
    ttlt: PhaseEstimate

    def row(self) -> Dict[str, float]:
        return {
            "arch": self.arch, "hw": self.hardware, "n_dev": self.n_devices,
            "mode": self.mode, "bsize": self.batch,
            "L": f"{self.prompt_len}+{self.gen_len}",
            "TTFT_ms": self.ttft.latency_s * 1e3,
            "J_per_prompt": self.ttft.joules,
            "TPOT_ms": self.tpot.latency_s * 1e3,
            "J_per_token": self.tpot.joules,
            "TTLT_ms": self.ttlt.latency_s * 1e3,
            "J_per_request": self.ttlt.joules,
        }


# ---------------------------------------------------------------------------
# analytic workload terms
# ---------------------------------------------------------------------------

def _attn_layers(cfg: ModelConfig):
    return [k for k in cfg.blocks() if k in ("attn", "local_attn")]


def attention_flops_prefill(cfg: ModelConfig, batch: int, seq: int) -> float:
    """QK^T + PV flops over the causal prefill, per full forward."""
    hd = cfg.resolved_head_dim
    total = 0.0
    for kind in _attn_layers(cfg):
        if kind == "local_attn" and cfg.sliding_window:
            ctx = min(cfg.sliding_window, seq)
            pairs = seq * ctx - ctx * (ctx - 1) / 2 if seq >= ctx else seq * (seq + 1) / 2
        else:
            pairs = seq * (seq + 1) / 2
        total += 4.0 * batch * cfg.num_heads * hd * pairs
    if cfg.is_encdec:
        enc = seq // 2
        total += 4.0 * batch * cfg.num_heads * hd * enc * enc * cfg.num_encoder_layers
        total += 4.0 * batch * cfg.num_heads * hd * seq * enc * len(_attn_layers(cfg))
    return total


def attention_flops_decode(cfg: ModelConfig, batch: int, kv_len: int) -> float:
    hd = cfg.resolved_head_dim
    total = 0.0
    for kind in _attn_layers(cfg):
        ctx = min(cfg.sliding_window, kv_len) if kind == "local_attn" else kv_len
        total += 4.0 * batch * cfg.num_heads * hd * ctx
    return total


def estimate_phase(
    *,
    name: str,
    flops: float,
    bytes_moved: float,
    collective_bytes: float,
    hw: HardwareSpec,
    n_devices: int,
    mode: str,
    overhead_s: float,
) -> PhaseEstimate:
    n_par = 1 if mode == "naive_pp" else n_devices
    compute_s = flops / max(n_par * hw.peak_flops_bf16 * hw.eta_compute, 1.0)
    memory_s = bytes_moved / max(n_par * hw.hbm_bw * hw.eta_memory, 1.0)
    coll_bw = max(hw.link_bw * hw.num_links * hw.eta_link, 1.0)
    collective_s = collective_bytes / coll_bw if n_devices > 1 else 0.0
    latency = max(compute_s, memory_s) + collective_s + overhead_s
    bound = max(
        (("compute", compute_s), ("memory", memory_s), ("collective", collective_s)),
        key=lambda kv: kv[1],
    )[0]
    c_frac = compute_s / latency
    m_frac = memory_s / latency
    tdp = hw.rail_tdp_watts or hw.tdp_watts
    idle = hw.rail_idle_watts if hw.rail_idle_watts >= 0 else hw.idle_watts
    if hw.kind == "edge":
        # GPU-rail sensor: DRAM traffic barely shows (see module doc)
        util = 0.7 * c_frac + 0.18 * m_frac
        idle = hw.rail_idle_watts if hw.rail_idle_watts >= 0 else idle
        per_dev = idle + tdp * ETA_POWER * util
    else:
        util = max(c_frac, m_frac)
        per_dev = idle + (tdp - idle) * ETA_POWER * util
    if mode == "naive_pp" and n_devices > 1:
        watts = per_dev + (n_devices - 1) * idle
    else:
        watts = per_dev * n_devices
    return PhaseEstimate(
        name=name, latency_s=latency, compute_s=compute_s, memory_s=memory_s,
        collective_s=collective_s, bound=bound, avg_watts=watts,
        joules=watts * latency, flops=flops, bytes_moved=bytes_moved,
    )


def estimate_workload(
    cfg: ModelConfig,
    *,
    hardware: str = "a6000",
    n_devices: int = 1,
    mode: str = "tp",
    batch: int = 1,
    prompt_len: int = 512,
    gen_len: int = 512,
    itemsize: int = 2,
) -> WorkloadEstimate:
    hw = get_hardware(hardware)
    size = size_prof.profile_size(cfg)
    param_bytes = size.param_bytes
    active_bytes = size.active_param_bytes
    active_params = size.active_param_count
    d = cfg.d_model

    # ---- TTFT (prefill) -----------------------------------------------------
    tokens = batch * prompt_len
    flops_pre = 2.0 * active_params * tokens + attention_flops_prefill(
        cfg, batch, prompt_len)
    cache_rep = cache_prof.profile_cache(cfg, batch, prompt_len + gen_len)
    act_bytes = 14.0 * tokens * d * (len(cfg.blocks()) + (cfg.num_encoder_layers or 0))
    kv_write = cache_rep.kv_bytes * min(1.0, prompt_len / max(prompt_len + gen_len, 1))
    bytes_pre = param_bytes + act_bytes + kv_write + cache_rep.state_bytes
    # tensor-parallel: 2 all-reduces of (tokens × d) per layer, ring ≈ 2(n-1)/n
    coll_pre = 0.0
    if n_devices > 1 and mode == "tp":
        ring = 2.0 * (n_devices - 1) / n_devices
        coll_pre = 2 * len(cfg.blocks()) * tokens * d * itemsize * ring
    ttft = estimate_phase(
        name="ttft", flops=flops_pre, bytes_moved=bytes_pre,
        collective_bytes=coll_pre, hw=hw, n_devices=n_devices, mode=mode,
        overhead_s=hw.launch_overhead_s * (len(cfg.blocks()) / 8 if mode == "naive_pp" else 1),
    )

    # ---- TPOT (one decode step at mid-generation KV length) ------------------
    kv_len = prompt_len + gen_len // 2
    cache_mid = cache_prof.profile_cache(cfg, batch, kv_len)
    flops_dec = 2.0 * active_params * batch + attention_flops_decode(cfg, batch, kv_len)
    bytes_dec = (
        active_bytes                      # stream active weights
        + cache_mid.kv_bytes              # read KV
        + 2.0 * cache_mid.state_bytes     # recurrent state read+write
        + cache_mid.cross_bytes
        + 2.0 * batch * d * len(cfg.blocks()) * itemsize * 14.0 / 14.0
    )
    coll_dec = 0.0
    if n_devices > 1 and mode == "tp":
        ring = 2.0 * (n_devices - 1) / n_devices
        coll_dec = 2 * len(cfg.blocks()) * batch * d * itemsize * ring
    tpot = estimate_phase(
        name="tpot", flops=flops_dec, bytes_moved=bytes_dec,
        collective_bytes=coll_dec, hw=hw, n_devices=n_devices, mode=mode,
        overhead_s=hw.launch_overhead_s,
    )

    # ---- TTLT ----------------------------------------------------------------
    lat = ttft.latency_s + max(gen_len - 1, 0) * tpot.latency_s
    joules = ttft.joules + max(gen_len - 1, 0) * tpot.joules
    ttlt = PhaseEstimate(
        name="ttlt", latency_s=lat,
        compute_s=ttft.compute_s + (gen_len - 1) * tpot.compute_s,
        memory_s=ttft.memory_s + (gen_len - 1) * tpot.memory_s,
        collective_s=ttft.collective_s + (gen_len - 1) * tpot.collective_s,
        bound=tpot.bound, avg_watts=joules / max(lat, 1e-9), joules=joules,
        flops=ttft.flops + (gen_len - 1) * tpot.flops,
        bytes_moved=ttft.bytes_moved + (gen_len - 1) * tpot.bytes_moved,
    )
    return WorkloadEstimate(
        arch=cfg.name, hardware=hardware, n_devices=n_devices, mode=mode,
        batch=batch, prompt_len=prompt_len, gen_len=gen_len,
        ttft=ttft, tpot=tpot, ttlt=ttlt,
    )
