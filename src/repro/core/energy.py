"""Energy profiling (paper §2.4), JAX/TPU adaptation.

The paper's method: a separate process samples instantaneous power at 10 Hz
(pynvml on server GPUs, jtop on Jetson), the average power over the latency
window is multiplied by the measured latency, and multi-GPU powers are
summed.  We reproduce the method exactly with a pluggable ``PowerReader``:

* ``NvmlReader``      — NVIDIA GPUs via pynvml (when available).
* ``JtopReader``      — Jetson on-board sensors via jetson-stats (when available).
* ``ProcStatReader``  — CPU dev rig: /proc/stat utilization × TDP model.
* ``ModelReader``     — utilization-scaled TDP model for hardware without a
  userspace power API (TPUs) or for estimator-mode accounting.
* ``SyntheticReader`` — deterministic waveform for tests.

``PowerMonitor`` runs the sampler in a background thread (the in-process
analogue of the paper's sampler process — JAX dispatch releases the GIL, so
a thread gives the same 10 Hz cadence without pickling device handles).
"""

from __future__ import annotations

import bisect
import dataclasses
import threading
import time
import warnings
from typing import Callable, List, Optional, Sequence, Tuple


class PowerReader:
    """Interface: instantaneous power in watts, one value per device."""

    def read_watts(self) -> Sequence[float]:  # pragma: no cover - interface
        raise NotImplementedError

    def close(self) -> None:
        pass


class SyntheticReader(PowerReader):
    def __init__(self, fn: Callable[[float], float], n_devices: int = 1):
        self._fn = fn
        self._n = n_devices
        self._t0 = time.perf_counter()

    def read_watts(self) -> Sequence[float]:
        w = self._fn(time.perf_counter() - self._t0)
        return [w] * self._n


class ModelReader(PowerReader):
    """Utilization-scaled TDP model (TPU has no userspace power API)."""

    def __init__(self, idle_watts: float, tdp_watts: float,
                 utilization_fn: Optional[Callable[[], float]] = None,
                 n_devices: int = 1):
        self.idle = idle_watts
        self.tdp = tdp_watts
        self.util_fn = utilization_fn or (lambda: 1.0)
        self._n = n_devices

    def read_watts(self) -> Sequence[float]:
        u = min(max(self.util_fn(), 0.0), 1.0)
        return [self.idle + (self.tdp - self.idle) * u] * self._n


class ProcStatReader(PowerReader):
    """CPU package power proxy from /proc/stat busy fraction × TDP."""

    def __init__(self, idle_watts: float = 10.0, tdp_watts: float = 65.0):
        self.idle = idle_watts
        self.tdp = tdp_watts
        self._last = self._read_stat()

    @staticmethod
    def _read_stat() -> Tuple[float, float]:
        with open("/proc/stat") as f:
            parts = f.readline().split()[1:]
        vals = [float(x) for x in parts[:8]]
        idle = vals[3] + vals[4]
        total = sum(vals)
        return idle, total

    def read_watts(self) -> Sequence[float]:
        idle, total = self._read_stat()
        last_idle, last_total = self._last
        self._last = (idle, total)
        d_total = total - last_total
        busy = 1.0 - (idle - last_idle) / d_total if d_total > 0 else 0.0
        return [self.idle + (self.tdp - self.idle) * busy]


class NvmlReader(PowerReader):  # pragma: no cover - needs NVIDIA hardware
    def __init__(self, device_indices: Optional[Sequence[int]] = None):
        import pynvml

        self._nvml = pynvml
        pynvml.nvmlInit()
        n = pynvml.nvmlDeviceGetCount()
        idx = list(device_indices) if device_indices else list(range(n))
        self._handles = [pynvml.nvmlDeviceGetHandleByIndex(i) for i in idx]

    def read_watts(self) -> Sequence[float]:
        return [self._nvml.nvmlDeviceGetPowerUsage(h) / 1000.0
                for h in self._handles]

    def close(self) -> None:
        self._nvml.nvmlShutdown()


class JtopReader(PowerReader):  # pragma: no cover - needs Jetson hardware
    def __init__(self):
        from jtop import jtop

        self._jtop = jtop()
        self._jtop.start()

    def read_watts(self) -> Sequence[float]:
        power = self._jtop.power
        return [power["rail"]["GPU"]["power"] / 1000.0]

    def close(self) -> None:
        self._jtop.close()


@dataclasses.dataclass
class EnergyResult:
    duration_s: float
    avg_watts: float            # summed across devices (paper: multi-GPU sum)
    joules: float
    samples: List[Tuple[float, List[float]]]  # (t, per-device watts)
    n_devices: int
    # achieved sampler rate over the window — the >= 5-10 Hz protocol
    # requirement is verifiable from the result, not assumed
    samples_per_sec: float = 0.0
    # reads that raised or returned empty (each leaves a gap the step
    # function backfills with the previous sample's power)
    dropped_reads: int = 0

    def per(self, count: int) -> float:
        """J/Token, J/Prompt, J/Request — divide by the unit count."""
        return self.joules / max(count, 1)


def integrate_joules(
    samples: Sequence[Tuple[float, Sequence[float]]], t0: float, t1: float
) -> float:
    """Energy over [t0, t1] treating the samples as a step function.

    Power at time t is the (device-summed) watts of the latest sample at or
    before t (the first sample extends backwards).  Because the step
    function is fixed, the integral is *additive* over adjacent windows:
    tiling [t0, t1] with sub-windows and summing reproduces the total
    exactly — the property per-request energy attribution relies on.
    """
    if t1 <= t0 or not samples:
        return 0.0
    ts = [t for t, _ in samples]
    ws = [sum(w) for _, w in samples]
    total = 0.0
    cur = t0
    # index of the sample governing time `cur`
    i = max(bisect.bisect_right(ts, cur) - 1, 0)
    while cur < t1:
        nxt = ts[i + 1] if i + 1 < len(ts) else t1
        seg_end = min(max(nxt, cur), t1)
        total += ws[i] * (seg_end - cur)
        cur = seg_end
        if i + 1 < len(ts) and ts[i + 1] <= cur:
            i += 1
    return total


class PowerMonitor:
    """10 Hz sampler thread; use as a context manager around a workload."""

    def __init__(self, reader: PowerReader, interval_s: float = 0.1):
        self.reader = reader
        self.interval_s = interval_s
        self._samples: List[Tuple[float, List[float]]] = []
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._t0 = 0.0
        self._t1 = 0.0
        self.dropped_reads = 0

    def _loop(self):
        # absolute-deadline scheduling: waiting ``interval_s`` *after* each
        # read lets slow reads (NVML can take ~ms) drift the achieved rate
        # below target; instead each wait targets t0 + k*interval, so read
        # latency eats into the idle wait, not the cadence
        deadline = self._t0 + self.interval_s
        while not self._stop.is_set():
            t = time.perf_counter()
            try:
                watts = list(self.reader.read_watts())
            except Exception:
                watts = []
            if watts:
                self._samples.append((t, watts))
            else:
                # a dropped read leaves a gap the step-function integral
                # backfills with stale power — count it, don't hide it
                self.dropped_reads += 1
            now = time.perf_counter()
            while deadline <= now:  # reads slower than the interval: skip
                deadline += self.interval_s
            self._stop.wait(deadline - now)

    def __enter__(self) -> "PowerMonitor":
        self._samples.clear()
        self.dropped_reads = 0
        self._stop.clear()
        self._t0 = time.perf_counter()
        self._t1 = 0.0
        # one synchronous sample so even sub-interval windows are covered
        try:
            self._samples.append((self._t0, list(self.reader.read_watts())))
        except Exception:
            self.dropped_reads += 1
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()
        return self

    def __exit__(self, *exc) -> None:
        self._t1 = time.perf_counter()
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
        if self.dropped_reads:
            warnings.warn(
                f"PowerMonitor dropped {self.dropped_reads} power reads "
                f"(reader raised or returned empty); the step-function "
                f"integral backfills those gaps with the previous sample",
                RuntimeWarning, stacklevel=2)

    @property
    def window(self) -> Tuple[float, float]:
        """(enter, exit) perf_counter stamps (exit == now while running)."""
        t1 = self._t1 if self._t1 > self._t0 else time.perf_counter()
        return self._t0, t1

    def joules_between(self, t0: float, t1: float) -> float:
        """Step-function energy over [t0, t1] (additive across windows)."""
        return integrate_joules(self._samples, t0, t1)

    def result(self) -> EnergyResult:
        t0, t1 = self.window
        duration = max(t1 - t0, 1e-9)
        window = [(t, w) for t, w in self._samples if t0 <= t <= t1 + 1e-3]
        if not window:
            window = self._samples[-1:] or [(t0, [0.0])]
        n_dev = max(len(w) for _, w in window)
        # one ledger: the run total is the same step-function integral
        # per-request attribution uses (``joules_between``), so tiling the
        # window with per-request sub-windows reproduces it exactly.  An
        # unweighted sample mean times the duration disagrees under
        # sampling jitter — the sub-windows then don't sum to the total.
        joules = integrate_joules(self._samples, t0, t1)
        return EnergyResult(
            duration_s=duration,
            avg_watts=joules / duration,
            joules=joules,
            samples=window,
            n_devices=n_dev,
            samples_per_sec=len(self._samples) / duration,
            dropped_reads=self.dropped_reads,
        )


class DeviceMonitorGroup:
    """One ``PowerMonitor`` per device under a single measurement window.

    The paper sums multi-GPU powers; this keeps the per-device ledgers
    intact instead of summing at the reader.  The group quacks like a
    ``PowerMonitor`` where the serving engine needs it (``window`` /
    ``joules_between`` / ``result`` / ``dropped_reads``) and adds the
    per-device split: ``joules_between_by_device`` for request-windowed
    tilings and ``result_by_device`` for run totals.  Every integral — per
    device, per window, aggregate — is the same step function, so

        sum_d integrate_d(t0, t1)  ==  group.joules_between(t0, t1)
        sum_d result_by_device()[d].joules  ==  result().joules

    and tiling the run window with request sub-windows reproduces the
    aggregate, exactly as in the single-monitor ledger.

    A device whose reader drops every read degrades gracefully: it
    contributes 0 J (no samples means no steps to integrate), its drops are
    counted in the aggregate ``dropped_reads``, and the other devices'
    ledgers are untouched.
    """

    def __init__(self, readers: Sequence[PowerReader], interval_s: float = 0.1):
        assert readers, "DeviceMonitorGroup needs at least one reader"
        self.monitors = [PowerMonitor(r, interval_s) for r in readers]
        self.interval_s = interval_s
        self._t0 = 0.0
        self._t1 = 0.0

    @property
    def n_devices(self) -> int:
        return len(self.monitors)

    @property
    def dropped_reads(self) -> int:
        return sum(m.dropped_reads for m in self.monitors)

    def __enter__(self) -> "DeviceMonitorGroup":
        # one clock for the group window; the per-device monitors stamp
        # their own t0 microseconds later, and their first synchronous
        # sample extends backwards over the gap (step-function semantics)
        self._t0 = time.perf_counter()
        self._t1 = 0.0
        for m in self.monitors:
            m.__enter__()
        return self

    def __exit__(self, *exc) -> None:
        self._t1 = time.perf_counter()
        for m in self.monitors:
            m.__exit__(*exc)

    @property
    def window(self) -> Tuple[float, float]:
        """(enter, exit) perf_counter stamps (exit == now while running)."""
        t1 = self._t1 if self._t1 > self._t0 else time.perf_counter()
        return self._t0, t1

    def joules_between(self, t0: float, t1: float) -> float:
        """Aggregate step-function energy over [t0, t1] (additive)."""
        return sum(self.joules_between_by_device(t0, t1))

    def joules_between_by_device(self, t0: float, t1: float) -> List[float]:
        return [m.joules_between(t0, t1) for m in self.monitors]

    def result_by_device(self) -> List[EnergyResult]:
        """Per-device results over the *group* window, so their joules sum
        exactly to ``result().joules``."""
        t0, t1 = self.window
        duration = max(t1 - t0, 1e-9)
        out = []
        for m in self.monitors:
            window = [(t, w) for t, w in m._samples if t0 <= t <= t1 + 1e-3]
            if not window:
                window = m._samples[-1:] or [(t0, [0.0])]
            joules = integrate_joules(m._samples, t0, t1)
            out.append(EnergyResult(
                duration_s=duration,
                avg_watts=joules / duration,
                joules=joules,
                samples=window,
                n_devices=max(len(w) for _, w in window),
                samples_per_sec=len(m._samples) / duration,
                dropped_reads=m.dropped_reads,
            ))
        return out

    def result(self) -> EnergyResult:
        per = self.result_by_device()
        duration = per[0].duration_s
        joules = sum(r.joules for r in per)
        # interleaved per-device samples, sorted by time — for inspection
        # only; the integrable ledgers live in the per-device monitors
        samples = sorted((s for m in self.monitors for s in m._samples),
                         key=lambda tw: tw[0])
        return EnergyResult(
            duration_s=duration,
            avg_watts=joules / duration,
            joules=joules,
            samples=samples,
            n_devices=len(self.monitors),
            # mean per-device achieved rate: one dead device lowers the
            # aggregate instead of zeroing it (its own rate is visible in
            # result_by_device)
            samples_per_sec=len(samples) / duration / len(self.monitors),
            dropped_reads=self.dropped_reads,
        )


def measure_energy(
    fn: Callable[[], object], reader: PowerReader, interval_s: float = 0.1
) -> EnergyResult:
    """Run ``fn`` under the sampler; energy = window-average power × latency."""
    import jax

    with PowerMonitor(reader, interval_s) as mon:
        jax.block_until_ready(fn())
    return mon.result()
