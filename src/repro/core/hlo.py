"""Compiled-HLO analysis: FLOPs / bytes from ``cost_analysis`` and
collective-traffic accounting parsed from the post-SPMD HLO text.

``cost_analysis()`` does not attribute collective traffic, so
``collective_stats`` scans ``compiled.as_text()`` (collectives only exist
after SPMD partitioning — the pre-partition ``lowered.as_text()`` has none)
and sums operand bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute op.  These are the §Roofline collective
terms.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Iterable, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    bytes_by_kind: Dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.counts.values())

    def fmt(self) -> str:
        if not self.counts:
            return "no collectives"
        parts = [
            f"{k}: {self.counts[k]}x / {self.bytes_by_kind[k]/1e6:.1f} MB"
            for k in sorted(self.counts)
        ]
        return ", ".join(parts)


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Sum result-shape bytes of every collective op in post-SPMD HLO.

    Result bytes are used (per the assignment: operand sizes ≈ the data a
    collective moves; for all-reduce operand==result, for all-gather the
    result is the full gathered tensor which is what transits the links).
    ``-start``/``-done`` async pairs are counted once (on ``-start``).
    """
    counts: Dict[str, int] = {}
    bytes_by_kind: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        # fast pre-filter
        if "all-" not in line and "reduce-scatter" not in line and \
                "collective-permute" not in line:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        if "-done(" in line:  # async completion: already counted at -start
            continue
        result_types, kind = m.group(1), m.group(2)
        nbytes = sum(
            _shape_bytes(dt, dims) for dt, dims in _SHAPE_RE.findall(result_types)
        )
        counts[kind] = counts.get(kind, 0) + 1
        bytes_by_kind[kind] = bytes_by_kind.get(kind, 0) + nbytes
    return CollectiveStats(counts=counts, bytes_by_kind=bytes_by_kind)


@dataclasses.dataclass
class CostSummary:
    flops: float
    transcendentals: float
    bytes_accessed: int
    output_bytes: int
    argument_bytes: int
    temp_bytes: int
    generated_code_bytes: int
    collectives: CollectiveStats

    def fmt(self) -> str:
        return (
            f"flops={self.flops:.3e} bytes={self.bytes_accessed:.3e} "
            f"args={self.argument_bytes/1e9:.2f}GB out={self.output_bytes/1e9:.2f}GB "
            f"temp={self.temp_bytes/1e9:.2f}GB | {self.collectives.fmt()}"
        )


def summarize_compiled(compiled, hlo_text: Optional[str] = None) -> CostSummary:
    """Extract the roofline inputs from a jax compiled executable."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # older jax returns [dict]
        cost = cost[0]
    mem = compiled.memory_analysis()

    def _mem(attr):
        return int(getattr(mem, attr, 0) or 0)

    text = hlo_text if hlo_text is not None else compiled.as_text()
    return CostSummary(
        flops=float(cost.get("flops", 0.0)),
        transcendentals=float(cost.get("transcendentals", 0.0)),
        bytes_accessed=int(cost.get("bytes accessed", 0)),
        output_bytes=int(cost.get("bytes accessed output", 0)),
        argument_bytes=_mem("argument_size_in_bytes"),
        temp_bytes=_mem("temp_size_in_bytes"),
        generated_code_bytes=_mem("generated_code_size_in_bytes"),
        collectives=collective_stats(text),
    )


def op_histogram(hlo_text: str, top: int = 25) -> List[Tuple[str, int]]:
    """Instruction-kind histogram of the optimized HLO (debug aid for remat
    waste: duplicate dot/fusion counts show recompute)."""
    hist: Dict[str, int] = {}
    op_re = re.compile(r"=\s*(?:\([^)]*\)|[a-z0-9\[\],{}]+)\s+([a-z][\w\-]*)\(")
    for line in hlo_text.splitlines():
        m = op_re.search(line)
        if m:
            hist[m.group(1)] = hist.get(m.group(1), 0) + 1
    return sorted(hist.items(), key=lambda kv: -kv[1])[:top]
