"""The ELANA public API: one object per model, all paper metrics behind it.

    from repro.core.profiler import Elana
    e = Elana("llama3.1-8b")                      # any registered arch
    e.size_report()                               # §2.2 model size
    e.cache_report(batch=128, seq_len=2048)       # §2.2 KV/SSM cache
    e.estimate(hardware="a6000", batch=1, ...)    # §2.3/2.4 estimator mode
    e.measure(batch=1, prompt_len=64, gen_len=16) # §2.3/2.4 measured mode
    e.trace(path="trace.json")                    # §2.5 Perfetto timeline

Custom architectures plug in exactly like the paper's
``_build_model_and_tokenizer`` hook: pass a ``ModelConfig`` (or a
``builder`` returning ``(cfg, params)``) instead of an arch name.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import cache as cache_prof
from repro.core import energy as energy_lib
from repro.core import estimator as est_lib
from repro.core import latency as lat_lib
from repro.core import size as size_prof
from repro.core import trace as trace_lib
from repro.core.hardware import get_hardware
from repro.models import model as model_lib
from repro.models.config import ModelConfig


class Elana:
    def __init__(
        self,
        arch: Optional[str] = None,
        *,
        config: Optional[ModelConfig] = None,
        builder: Optional[Callable[[], Tuple[ModelConfig, Dict]]] = None,
        smoke: bool = False,
        seed: int = 0,
    ):
        if builder is not None:
            self.cfg, self._params = builder()
        else:
            if config is not None:
                self.cfg = config
            else:
                from repro.configs import get_config

                assert arch is not None, "need arch, config= or builder="
                self.cfg = get_config(arch, smoke=smoke)
            self._params = None
        self._seed = seed
        self._lat: Optional[lat_lib.LatencyProfiler] = None

    # -- lazy param materialization (measured mode only) ----------------------
    @property
    def params(self):
        if self._params is None:
            self._params, _ = model_lib.init(self.cfg, jax.random.PRNGKey(self._seed))
        return self._params

    def _latency_profiler(self) -> lat_lib.LatencyProfiler:
        if self._lat is None:
            self._lat = lat_lib.LatencyProfiler(self.cfg, self.params, seed=self._seed)
        return self._lat

    # -- §2.2 sizes ------------------------------------------------------------
    def size_report(self) -> size_prof.SizeReport:
        return size_prof.profile_size(self.cfg, self._params)

    def cache_report(self, batch: int, seq_len: int) -> cache_prof.CacheReport:
        return cache_prof.profile_cache(self.cfg, batch, seq_len)

    # -- §2.3 measured latency ---------------------------------------------------
    def measure(
        self,
        batch: int = 1,
        prompt_len: int = 64,
        gen_len: int = 16,
        iters: int = 5,
        power_reader: Optional[energy_lib.PowerReader] = None,
    ) -> Dict[str, float]:
        """Measured TTFT/TPOT/TTLT (+ energy when a PowerReader is given)."""
        lp = self._latency_profiler()
        out: Dict[str, float] = {}
        if power_reader is None:
            ttft = lp.ttft(batch, prompt_len, iters=iters)
            tpot = lp.tpot(batch, prompt_len, gen_len=max(gen_len, 4))
            ttlt = lp.ttlt(batch, prompt_len, gen_len, iters=max(2, iters // 2))
            out.update(ttft_ms=ttft.mean_ms, tpot_ms=tpot.mean_ms,
                       ttlt_ms=ttlt.mean_ms,
                       ttft_p95_ms=ttft.p95_s * 1e3, tpot_p95_ms=tpot.p95_s * 1e3)
        else:
            mon = energy_lib.PowerMonitor(power_reader)
            with mon:
                ttft = lp.ttft(batch, prompt_len, iters=iters)
            e = mon.result()
            out.update(ttft_ms=ttft.mean_ms,
                       j_per_prompt=e.joules / (iters * batch))
            with mon:
                tpot = lp.tpot(batch, prompt_len, gen_len=max(gen_len, 4))
            e = mon.result()
            out.update(tpot_ms=tpot.mean_ms,
                       j_per_token=e.joules / (max(gen_len, 4)))
            with mon:
                ttlt = lp.ttlt(batch, prompt_len, gen_len, iters=2)
            e = mon.result()
            out.update(ttlt_ms=ttlt.mean_ms, j_per_request=e.joules / 2)
        return out

    # -- §2.3/2.4 estimator mode --------------------------------------------------
    def estimate(
        self,
        hardware: str = "tpu-v5e",
        n_devices: int = 1,
        mode: str = "tp",
        batch: int = 1,
        prompt_len: int = 512,
        gen_len: int = 512,
    ) -> est_lib.WorkloadEstimate:
        return est_lib.estimate_workload(
            self.cfg, hardware=hardware, n_devices=n_devices, mode=mode,
            batch=batch, prompt_len=prompt_len, gen_len=gen_len,
        )

    # -- §2.5 kernel-level trace ---------------------------------------------------
    def trace(
        self,
        path: str,
        hardware: str = "tpu-v5e",
        phase: str = "decode",
        batch: int = 1,
        seq_len: int = 1024,
    ) -> Dict[str, float]:
        events = trace_lib.estimated_timeline(
            self.cfg, hardware=hardware, phase=phase, batch=batch, seq_len=seq_len,
        )
        trace_lib.to_chrome_trace(events, path, meta={
            "arch": self.cfg.name, "hardware": hardware, "phase": phase,
            "batch": batch, "seq_len": seq_len,
        })
        return trace_lib.timeline_summary(events)
