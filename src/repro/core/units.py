"""Memory-unit conventions (paper §2.2).

ELANA reports sizes in SI units by default (1 GB = 1000³ bytes — the storage-
manufacturer convention the paper adopts) with binary units (1 GiB = 1024³)
as an option.
"""

from __future__ import annotations

from typing import Literal

Unit = Literal["B", "KB", "MB", "GB", "TB", "KiB", "MiB", "GiB", "TiB"]

_SI = {"B": 1, "KB": 1000, "MB": 1000**2, "GB": 1000**3, "TB": 1000**4}
_BIN = {"B": 1, "KiB": 1024, "MiB": 1024**2, "GiB": 1024**3, "TiB": 1024**4}
FACTORS = {**_SI, **_BIN}


def convert(num_bytes: int, unit: Unit = "GB") -> float:
    """Convert a byte count to the requested unit."""
    return num_bytes / FACTORS[unit]


def fmt_bytes(num_bytes: int, unit: Unit = "GB", digits: int = 2) -> str:
    return f"{convert(num_bytes, unit):.{digits}f} {unit}"


def auto_unit(num_bytes: int, binary: bool = False) -> Unit:
    """Pick the largest unit that keeps the value >= 1."""
    table = _BIN if binary else _SI
    best = "B"
    for unit, factor in table.items():
        if num_bytes >= factor:
            best = unit
    return best


def fmt_auto(num_bytes: int, binary: bool = False, digits: int = 2) -> str:
    return fmt_bytes(num_bytes, auto_unit(num_bytes, binary), digits)


def fmt_duration(seconds: float) -> str:
    if seconds < 1e-3:
        return f"{seconds * 1e6:.2f} us"
    if seconds < 1.0:
        return f"{seconds * 1e3:.2f} ms"
    return f"{seconds:.2f} s"
