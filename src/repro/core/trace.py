"""Kernel-level timeline profiling (paper §2.5), exported for Perfetto.

Two paths, mirroring the paper's PyTorch-Profiler→Perfetto flow:

* ``capture_jax_trace`` — wraps ``jax.profiler.trace`` for real-hardware runs
  (the produced TensorBoard trace is Perfetto-loadable).
* ``estimated_timeline`` — op-granular roofline timeline derived from the
  model structure + hardware spec, exported as chrome-trace JSON
  (``ui.perfetto.dev`` opens it directly).  This works on the CPU dev
  container and is also the visual companion of the §Roofline numbers:
  each op event carries its FLOPs, bytes and bound-ness in ``args``.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional

from repro.core.hardware import HardwareSpec, get_hardware
from repro.models.config import ModelConfig


@dataclasses.dataclass
class OpEvent:
    name: str
    dur_s: float
    flops: float
    bytes_moved: float
    bound: str
    category: str


def _op_time(hw: HardwareSpec, flops: float, bytes_moved: float):
    ct = flops / (hw.peak_flops_bf16 * hw.eta_compute)
    mt = bytes_moved / (hw.hbm_bw * hw.eta_memory)
    return max(ct, mt), ("compute" if ct >= mt else "memory")


def _block_ops(cfg: ModelConfig, kind: str, tokens: int, kv_len: int,
               decode: bool, itemsize: int = 2) -> List[Dict]:
    """Analytic (flops, bytes) per op inside one block."""
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    h, kv = cfg.num_heads, cfg.num_kv_heads
    ops: List[Dict] = []

    def op(name, flops, bytes_moved, cat):
        ops.append(dict(name=name, flops=flops, bytes=bytes_moved, cat=cat))

    norm_bytes = 2 * tokens * d * itemsize
    if kind in ("attn", "local_attn"):
        wq = d * h * hd
        wkv = 2 * d * kv * hd
        wo = h * hd * d
        op("rmsnorm", 6.0 * tokens * d, norm_bytes, "norm")
        op("qkv_proj", 2.0 * tokens * (wq + wkv),
           (wq + wkv) * itemsize + tokens * d * itemsize, "gemm")
        ctx = min(cfg.sliding_window, kv_len) if kind == "local_attn" and \
            cfg.sliding_window else kv_len
        a_flops = 4.0 * tokens * h * hd * (ctx if decode else ctx / 2)
        # flash-tiled KV traffic: the KV stream is re-read once per q block
        batch = max(tokens // max(kv_len, 1), 1) if not decode else tokens
        q_passes = 1 if decode else max((tokens // batch) // 1024, 1)
        a_bytes = (2 * batch * ctx * kv * hd * itemsize * q_passes
                   + 2 * tokens * h * hd * itemsize)  # + Q read / O write
        op("attention", a_flops, a_bytes, "attn")
        op("out_proj", 2.0 * tokens * wo, wo * itemsize + tokens * d * itemsize, "gemm")
        if cfg.is_moe:
            k = cfg.num_experts_per_tok
            wff = 3 * d * cfg.d_ff
            op("rmsnorm", 6.0 * tokens * d, norm_bytes, "norm")
            op("moe_route", 2.0 * tokens * d * cfg.num_experts,
               tokens * cfg.num_experts * 4, "gemm")
            active_w = wff * min(cfg.num_experts, k * max(tokens, 1)) \
                if decode else wff * cfg.num_experts
            op("moe_experts", 2.0 * tokens * k * wff, active_w * itemsize, "gemm")
            if cfg.num_shared_experts:
                wsh = 3 * d * cfg.d_ff * cfg.num_shared_experts
                op("moe_shared", 2.0 * tokens * wsh, wsh * itemsize, "gemm")
        else:
            wff = (3 if cfg.mlp_gated else 2) * d * cfg.d_ff
            op("rmsnorm", 6.0 * tokens * d, norm_bytes, "norm")
            op("mlp", 2.0 * tokens * wff, wff * itemsize + tokens * d * itemsize, "gemm")
    elif kind == "ffn":
        wff = (3 if cfg.mlp_gated else 2) * d * cfg.d_ff
        op("rmsnorm", 6.0 * tokens * d, norm_bytes, "norm")
        op("mlp", 2.0 * tokens * wff, wff * itemsize + tokens * d * itemsize, "gemm")
    elif kind == "rglru":
        W = cfg.resolved_lru_width
        op("rmsnorm", 6.0 * tokens * d, norm_bytes, "norm")
        op("rglru_proj", 2.0 * tokens * 2 * d * W, 2 * d * W * itemsize, "gemm")
        op("rglru_scan", 10.0 * tokens * W, 3 * tokens * W * 4, "scan")
        op("rglru_out", 2.0 * tokens * W * d, W * d * itemsize, "gemm")
        wff = (3 if cfg.mlp_gated else 2) * d * cfg.d_ff
        op("mlp", 2.0 * tokens * wff, wff * itemsize, "gemm")
    elif kind in ("mlstm", "slstm"):
        W = int(d * cfg.mlstm_proj_factor) if kind == "mlstm" else d
        H = cfg.resolved_rec_heads
        Dh = W // H
        op("rmsnorm", 6.0 * tokens * d, norm_bytes, "norm")
        op(f"{kind}_proj", 2.0 * tokens * (2 * d * W + 3 * W * Dh),
           (2 * d * W + 3 * H * Dh * Dh) * itemsize, "gemm")
        state = H * Dh * Dh * 4
        op(f"{kind}_cell", 8.0 * tokens * H * Dh * Dh / max(1, 1),
           (tokens * W * 4 + 2 * state * (tokens if decode else tokens / 64)), "scan")
        op(f"{kind}_out", 2.0 * tokens * W * d, W * d * itemsize, "gemm")
    return ops


def estimated_timeline(
    cfg: ModelConfig,
    *,
    hardware: str = "tpu-v5e",
    phase: str = "decode",
    batch: int = 1,
    seq_len: int = 1024,
) -> List[OpEvent]:
    hw = get_hardware(hardware)
    decode = phase == "decode"
    tokens = batch * (1 if decode else seq_len)
    events: List[OpEvent] = []
    emb_bytes = cfg.vocab_size * cfg.d_model * 2
    emb_dur, _ = _op_time(hw, 0, tokens * cfg.d_model * 2)
    events.append(OpEvent("embed", emb_dur, 0, tokens * cfg.d_model * 2,
                          "memory", "gather"))
    for li, kind in enumerate(cfg.blocks()):
        for o in _block_ops(cfg, kind, tokens, seq_len, decode):
            dur, bound = _op_time(hw, o["flops"], o["bytes"])
            events.append(OpEvent(
                f"L{li:02d}/{o['name']}", dur, o["flops"], o["bytes"], bound,
                o["cat"],
            ))
    lm_flops = 2.0 * tokens * cfg.d_model * cfg.vocab_size
    dur, bound = _op_time(hw, lm_flops, emb_bytes)
    events.append(OpEvent("lm_head", dur, lm_flops, emb_bytes, bound, "gemm"))
    return events


def to_chrome_trace(events: List[OpEvent], path: str,
                    meta: Optional[Dict] = None) -> str:
    """Write a Perfetto-loadable chrome-trace JSON; returns the path."""
    trace = {"traceEvents": [], "displayTimeUnit": "ns",
             "metadata": meta or {}}
    ts = 0.0
    for ev in events:
        trace["traceEvents"].append({
            "name": ev.name, "ph": "X", "ts": ts * 1e6, "dur": ev.dur_s * 1e6,
            "pid": 0, "tid": 0, "cat": ev.category,
            "args": {"flops": ev.flops, "bytes": ev.bytes_moved,
                     "bound": ev.bound},
        })
        ts += ev.dur_s
    with open(path, "w") as f:
        json.dump(trace, f)
    return path


def timeline_summary(events: List[OpEvent]) -> Dict[str, float]:
    total = sum(e.dur_s for e in events)
    by_cat: Dict[str, float] = {}
    for e in events:
        by_cat[e.category] = by_cat.get(e.category, 0.0) + e.dur_s
    out = {"total_s": total}
    out.update({f"{k}_s": v for k, v in sorted(by_cat.items())})
    out["memory_bound_frac"] = sum(
        e.dur_s for e in events if e.bound == "memory") / max(total, 1e-12)
    return out


def capture_jax_trace(path: str, fn, *args, **kwargs):
    """Real-hardware trace via jax.profiler (TensorBoard/Perfetto format)."""
    import jax

    with jax.profiler.trace(path):
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
    return out
