"""Measured-mode latency profiling: TTFT / TPOT / TTLT (paper §2.3).

Semantics follow the paper:

* **TTFT** — latency of the prefill forward pass.  Prompts are random; the
  prefill executable is *not* pre-warmed across prompt lengths (the paper
  does not CUDA-graph-cache prefill) — each distinct prompt length pays its
  own compile, which we report separately as ``compile_s``.
* **TPOT** — inter-token interval during autoregressive decode with a
  prefilled cache, using an AOT-compiled ``decode_step`` replayed across
  steps (the jit analogue of the paper's CUDA-graph-cached generation).
* **TTLT** — end-to-end prefill + generation for a batch of requests.

All timings use host ``perf_counter`` around ``jax.block_until_ready`` —
the device-synchronization equivalent of ``torch.cuda.synchronize``.
"""

from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.models import model as model_lib
from repro.models.config import ModelConfig


@dataclasses.dataclass
class LatencyStats:
    name: str
    samples_s: List[float]
    compile_s: float = 0.0

    @property
    def mean_s(self) -> float:
        return statistics.fmean(self.samples_s)

    @property
    def std_s(self) -> float:
        return statistics.pstdev(self.samples_s) if len(self.samples_s) > 1 else 0.0

    @property
    def p50_s(self) -> float:
        return statistics.median(self.samples_s)

    @property
    def p95_s(self) -> float:
        xs = sorted(self.samples_s)
        return xs[min(len(xs) - 1, int(0.95 * len(xs)))]

    @property
    def mean_ms(self) -> float:
        return self.mean_s * 1e3

    def summary(self) -> Dict[str, float]:
        return {
            "name": self.name, "mean_ms": self.mean_ms,
            "std_ms": self.std_s * 1e3, "p50_ms": self.p50_s * 1e3,
            "p95_ms": self.p95_s * 1e3, "n": len(self.samples_s),
            "compile_ms": self.compile_s * 1e3,
        }


def time_callable(
    fn: Callable[[], object], iters: int = 10, warmup: int = 2, name: str = "fn"
) -> LatencyStats:
    t0 = time.perf_counter()
    for _ in range(max(warmup, 1)):
        jax.block_until_ready(fn())
    compile_s = time.perf_counter() - t0
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        samples.append(time.perf_counter() - t0)
    return LatencyStats(name=name, samples_s=samples, compile_s=compile_s)


class LatencyProfiler:
    """TTFT / TPOT / TTLT measurement for one model + workload."""

    def __init__(self, cfg: ModelConfig, params, *, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.key = jax.random.PRNGKey(seed)
        self._prefill_jit = jax.jit(
            lambda p, batch, cache: model_lib.prefill(cfg, p, batch, cache)
        )
        self._decode_jit = jax.jit(
            lambda p, tok, pos, cache: model_lib.decode_step(cfg, p, tok, pos, cache)
        )

    # -- helpers -------------------------------------------------------------
    def _random_batch(self, batch: int, prompt_len: int) -> Dict:
        cfg = self.cfg
        self.key, k1, k2, k3 = jax.random.split(self.key, 4)
        tok_len = prompt_len
        out: Dict = {}
        if cfg.num_vision_tokens:
            tok_len = max(1, prompt_len - cfg.num_vision_tokens)
            out["vision_embeds"] = 0.1 * jax.random.normal(
                k2, (batch, cfg.num_vision_tokens, cfg.d_model), jnp.dtype(cfg.dtype)
            )
        out["tokens"] = jax.random.randint(k1, (batch, tok_len), 0, cfg.vocab_size)
        if cfg.is_encdec:
            out["enc_embeds"] = 0.1 * jax.random.normal(
                k3, (batch, max(prompt_len // 2, 1), cfg.d_model), jnp.dtype(cfg.dtype)
            )
        return out

    def _fresh_cache(self, batch: int, max_len: int):
        return model_lib.init_cache(self.cfg, batch, max_len, jnp.dtype(self.cfg.dtype))

    # -- metrics ---------------------------------------------------------------
    def ttft(self, batch: int, prompt_len: int, iters: int = 10,
             warmup: int = 2) -> LatencyStats:
        """Prefill latency; fresh random prompt each run (paper §2.3)."""
        max_len = prompt_len + 1
        cache = self._fresh_cache(batch, max_len)
        samples, t_compile = [], 0.0
        for i in range(warmup + iters):
            b = self._random_batch(batch, prompt_len)
            t0 = time.perf_counter()
            logits, _ = self._prefill_jit(self.params, b, cache)
            jax.block_until_ready(logits)
            dt = time.perf_counter() - t0
            if i < warmup:
                t_compile += dt
            else:
                samples.append(dt)
        return LatencyStats(name="ttft", samples_s=samples, compile_s=t_compile)

    def tpot(self, batch: int, prompt_len: int, gen_len: int = 32,
             warmup: int = 2) -> LatencyStats:
        """Per-token decode latency after prefilling a random prompt."""
        max_len = prompt_len + gen_len + 1
        cache = self._fresh_cache(batch, max_len)
        b = self._random_batch(batch, prompt_len)
        logits, cache = jax.block_until_ready(
            self._prefill_jit(self.params, b, cache))
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        # warm the decode executable (CUDA-graph analogue: compile once)
        t0 = time.perf_counter()
        for i in range(warmup):
            _l, _c = self._decode_jit(
                self.params, tok, jnp.asarray(prompt_len + 0, jnp.int32), cache)
            jax.block_until_ready(_l)
        compile_s = time.perf_counter() - t0
        samples = []
        pos = prompt_len
        for i in range(gen_len):
            t0 = time.perf_counter()
            logits, cache = self._decode_jit(
                self.params, tok, jnp.asarray(pos, jnp.int32), cache)
            jax.block_until_ready(logits)
            samples.append(time.perf_counter() - t0)
            tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            pos += 1
        return LatencyStats(name="tpot", samples_s=samples, compile_s=compile_s)

    def ttlt(self, batch: int, prompt_len: int, gen_len: int,
             iters: int = 3) -> LatencyStats:
        """End-to-end request latency: prefill + gen_len decode steps."""
        max_len = prompt_len + gen_len + 1
        # warm both executables
        self.ttft(batch, prompt_len, iters=1, warmup=1)
        self.tpot(batch, prompt_len, gen_len=1, warmup=1)
        samples = []
        for _ in range(iters):
            cache = self._fresh_cache(batch, max_len)
            b = self._random_batch(batch, prompt_len)
            t0 = time.perf_counter()
            logits, cache = self._prefill_jit(self.params, b, cache)
            tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            for i in range(gen_len):
                logits, cache = self._decode_jit(
                    self.params, tok, jnp.asarray(prompt_len + i, jnp.int32), cache)
                tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            jax.block_until_ready(logits)
            samples.append(time.perf_counter() - t0)
        return LatencyStats(name="ttlt", samples_s=samples)
