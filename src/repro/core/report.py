"""Report rendering: the paper's Table-2/3/4 layouts as markdown / CSV."""

from __future__ import annotations

import io
from typing import Dict, Iterable, List, Optional, Sequence


def to_markdown(rows: Sequence[Dict], columns: Optional[List[str]] = None,
                floatfmt: str = ".2f") -> str:
    if not rows:
        return "(empty)"
    cols = columns or list(rows[0].keys())

    def cell(v):
        if isinstance(v, float):
            return format(v, floatfmt)
        return str(v)

    widths = {c: max(len(c), *(len(cell(r.get(c, ""))) for r in rows)) for c in cols}
    out = ["| " + " | ".join(c.ljust(widths[c]) for c in cols) + " |"]
    out.append("|" + "|".join("-" * (widths[c] + 2) for c in cols) + "|")
    for r in rows:
        out.append("| " + " | ".join(cell(r.get(c, "")).ljust(widths[c]) for c in cols) + " |")
    return "\n".join(out)


def to_csv(rows: Sequence[Dict], columns: Optional[List[str]] = None) -> str:
    if not rows:
        return ""
    cols = columns or list(rows[0].keys())
    buf = io.StringIO()
    buf.write(",".join(cols) + "\n")
    for r in rows:
        buf.write(",".join(str(r.get(c, "")) for c in cols) + "\n")
    return buf.getvalue()


def table2_rows(size_reports, cache_reports_by_workload) -> List[Dict]:
    """Paper Table 2: params + cache sizes across (bsize, L) workloads."""
    rows = []
    for rep in size_reports:
        row = {"Model": rep.name, "Param.": f"{rep.param_bytes/1e9:.2f} GB"}
        for (bsize, L), cache_rep in cache_reports_by_workload.get(rep.name, {}).items():
            row[f"bsize={bsize}, L={L}"] = f"{cache_rep.total_bytes/1e9:.2f} GB"
        rows.append(row)
    return rows


def serving_summary_rows(summary: Dict) -> List[Dict]:
    """ELANA serving metrics: mean + p50/p95/p99 per latency family."""
    rows = []
    for name, label in (("ttft", "TTFT"), ("tpot", "TPOT"), ("ttlt", "TTLT")):
        if f"{name}_ms" not in summary:
            continue
        rows.append({
            "Metric": label,
            "mean(ms)": round(summary[f"{name}_ms"], 2),
            "p50(ms)": round(summary.get(f"{name}_p50_ms", 0.0), 2),
            "p95(ms)": round(summary.get(f"{name}_p95_ms", 0.0), 2),
            "p99(ms)": round(summary.get(f"{name}_p99_ms", 0.0), 2),
        })
    return rows


def serving_client_rows(summary: Dict) -> List[Dict]:
    """Client-side steady-state view (loadgen over the HTTP server):
    achieved rates, client latencies, client-vs-engine deltas, and the
    energy ledger for the measured window."""
    rows = []
    for key, label in (("steady_requests", "steady-state requests"),
                       ("steady_window_s", "window (s)"),
                       ("achieved_qps", "achieved req/s"),
                       ("client_tokens_per_sec", "client tokens/s"),
                       ("client_ttft_ms", "client TTFT mean (ms)"),
                       ("client_ttft_p95_ms", "client TTFT p95 (ms)"),
                       ("client_tpot_ms", "client TPOT mean (ms)"),
                       ("client_ttlt_ms", "client TTLT mean (ms)"),
                       ("ttft_client_minus_engine_ms",
                        "TTFT client-engine delta (ms)"),
                       ("tpot_client_minus_engine_ms",
                        "TPOT client-engine delta (ms)"),
                       ("joules_total", "window energy (J)"),
                       ("joules_attributed", "sum of request windows (J)"),
                       ("joules_per_request", "J/request"),
                       ("joules_per_token", "J/token"),
                       ("avg_watts", "avg power (W)"),
                       ("power_samples_per_sec", "power sample rate (Hz)"),
                       ("power_reads_dropped", "power reads dropped"),
                       ("warmup_excluded", "warmup requests excluded"),
                       ("errors", "client errors")):
        if key in summary:
            rows.append({"Metric": label, "value": round(summary[key], 3)})
    return rows


def serving_throughput_rows(summary: Dict) -> List[Dict]:
    """Engine-step economics: how much work each step moved and how many
    device dispatches it took (the unified mixed step targets <= 2)."""
    rows = []
    for key, label in (("tokens_per_sec", "tokens/s"),
                       ("decode_tokens_per_sec", "decode tokens/s"),
                       ("prefill_tokens_per_sec", "prefill tokens/s"),
                       ("steps_per_sec", "steps/s"),
                       ("tokens_per_dispatch", "tokens/dispatch"),
                       ("spec_accept_rate", "spec accept rate"),
                       ("drafted_tokens", "drafted tokens"),
                       ("accepted_tokens", "accepted tokens"),
                       ("power_samples_per_sec", "power sample rate (Hz)"),
                       ("power_reads_dropped", "power reads dropped")):
        if key in summary:
            rows.append({"Metric": label,
                         "value": round(summary[key], 2)})
    if "dispatches_per_step_p50" in summary:
        rows.append({"Metric": "dispatches/step p50",
                     "value": round(summary["dispatches_per_step_p50"], 2)})
        rows.append({"Metric": "dispatches/step p95",
                     "value": round(summary["dispatches_per_step_p95"], 2)})
    # per-device splits from a --tp run: list values render as a / b / c
    for key, label, fmt in (
            ("joules_per_device", "J by device", "{:.2f}"),
            ("kv_bytes_peak_per_device", "KV peak bytes by device", "{:d}"),
            ("pool_blocks_in_use_per_device", "pool blocks by device", "{:d}"),
            ("power_samples_per_sec_per_device",
             "power sample rate by device (Hz)", "{:.1f}")):
        if key in summary:
            rows.append({"Metric": label, "value": " / ".join(
                fmt.format(v) for v in summary[key])})
    return rows


def serving_request_rows(requests) -> List[Dict]:
    """Per-request table: latency + attributed energy (paper §2.4)."""
    rows = []
    for r in requests:
        rows.append({
            "Req": r.uid,
            "Prompt": len(r.prompt),
            "Out": len(r.output_tokens),
            "TTFT(ms)": round(r.ttft_s * 1e3, 1),
            "TTLT(ms)": round(r.ttlt_s * 1e3, 1),
            "J/Req": round(r.joules, 3),
            "Trunc": "y" if r.truncated else "",
        })
    return rows


def table3_rows(estimates) -> List[Dict]:
    """Paper Table 3/4: TTFT / J/Prom / TPOT / J/Tok / TTLT / J/Req."""
    rows = []
    for est in estimates:
        rows.append({
            "Model": est.arch,
            "HW": f"{est.hardware} x{est.n_devices}",
            "Workload": f"bsize={est.batch}, L={est.prompt_len}+{est.gen_len}",
            "TTFT(ms)": round(est.ttft.latency_s * 1e3, 2),
            "J/Prom.": round(est.ttft.joules, 2),
            "TPOT(ms)": round(est.tpot.latency_s * 1e3, 2),
            "J/Tok.": round(est.tpot.joules, 2),
            "TTLT(ms)": round(est.ttlt.latency_s * 1e3, 2),
            "J/Req.": round(est.ttlt.joules, 2),
        })
    return rows
