"""Model-size profiling (paper §2.2, Table 2).

Sizes are derived from ``jax.eval_shape`` over the real ``init`` function —
i.e. the *exact* parameter tree the runtime allocates, with zero device
memory touched.  This is the TPU/JAX analogue of ELANA walking
``model.parameters()`` / ``model.buffers()``: trainable weights and
auxiliary buffers (e.g. the RG-LRU Λ constants) are both counted because
both live in the params pytree.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import units
from repro.models import model as model_lib
from repro.models.config import ModelConfig


@dataclasses.dataclass
class SizeReport:
    name: str
    param_count: int                      # total parameters (incl. buffers)
    param_bytes: int
    active_param_count: int               # MoE: per-token activated params
    active_param_bytes: int
    by_component: Dict[str, int]          # component -> bytes
    dtype: str

    def fmt(self, unit: str = "GB") -> str:
        lines = [
            f"model: {self.name}",
            f"  params: {self.param_count/1e9:.3f} B "
            f"({units.fmt_bytes(self.param_bytes, unit)}, {self.dtype})",
        ]
        if self.active_param_count != self.param_count:
            lines.append(
                f"  active params/token: {self.active_param_count/1e9:.3f} B "
                f"({units.fmt_bytes(self.active_param_bytes, unit)})"
            )
        for comp, nbytes in sorted(self.by_component.items(), key=lambda kv: -kv[1]):
            lines.append(f"    {comp:<28s} {units.fmt_bytes(nbytes, unit)}")
        return "\n".join(lines)


def _shape_tree(cfg: ModelConfig):
    """Parameter ShapeDtypeStruct tree without allocating anything."""
    return jax.eval_shape(
        lambda key: model_lib.init(cfg, key)[0], jax.random.PRNGKey(0)
    )


def _leaf_bytes(leaf) -> int:
    return int(leaf.size) * jnp.dtype(leaf.dtype).itemsize


def _component(path) -> str:
    """Group leaf paths into human-meaningful components."""
    keys = [getattr(p, "key", getattr(p, "name", str(p))) for p in path]
    if keys[0] in ("embed", "lm_head"):
        return keys[0]
    # decoder/encoder -> groups/rest -> <idx> -> block part
    stack = keys[0]
    part = None
    for k in keys[1:]:
        if k in ("attn", "cross", "mlp", "rec", "cell") or k.startswith("norm"):
            part = k
            break
    if part is None:
        part = keys[-2] if len(keys) > 1 else keys[-1]
    if "norm" in part or part == "scale":
        part = "norms"
    return f"{stack}.{part}"


def moe_active_fraction(cfg: ModelConfig) -> float:
    """Fraction of expert weights active per token (1.0 for dense)."""
    if not cfg.is_moe:
        return 1.0
    return cfg.num_experts_per_tok / cfg.num_experts


def profile_size(cfg: ModelConfig, params=None) -> SizeReport:
    """Size report from config (eval_shape) or a concrete params tree."""
    tree = params if params is not None else _shape_tree(cfg)
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    total_count = 0
    total_bytes = 0
    by_comp: Dict[str, int] = {}
    expert_count = 0
    expert_bytes = 0
    for path, leaf in flat:
        n, b = int(leaf.size), _leaf_bytes(leaf)
        total_count += n
        total_bytes += b
        comp = _component(path)
        by_comp[comp] = by_comp.get(comp, 0) + b
        keys = [str(getattr(p, "key", p)) for p in path]
        if cfg.is_moe and any(k in ("wg", "wu", "wd") for k in keys) and \
                "shared" not in keys and any(k == "mlp" for k in keys):
            expert_count += n
            expert_bytes += b
    frac = moe_active_fraction(cfg)
    active_count = total_count - expert_count + int(expert_count * frac)
    active_bytes = total_bytes - expert_bytes + int(expert_bytes * frac)
    return SizeReport(
        name=cfg.name,
        param_count=total_count,
        param_bytes=total_bytes,
        active_param_count=active_count,
        active_param_bytes=active_bytes,
        by_component=by_comp,
        dtype=str(cfg.param_dtype),
    )
