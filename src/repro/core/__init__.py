"""ELANA core: the paper's profiling contribution, JAX/TPU-native.

Submodules: units, hardware, size, cache, latency, energy, estimator, hlo,
trace, report, profiler (the ``Elana`` orchestrator).
"""
from repro.core.profiler import Elana  # noqa: F401
