"""Unified model configuration for every supported architecture family.

A single ``ModelConfig`` describes dense decoders, MoE decoders, recurrent
(xLSTM) stacks, hybrid (RG-LRU + local attention) stacks, encoder-decoder
models, and multimodal backbones.  The layer stack is expressed as a
``block_pattern`` that tiles across ``num_layers`` (e.g. RecurrentGemma's
``('rglru', 'rglru', 'local_attn')``), which is what lets one scan-based
model implementation cover all ten assigned architectures.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

# Block kinds understood by models/model.py.
BLOCK_KINDS = ("attn", "local_attn", "ffn", "rglru", "mlstm", "slstm")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # -- identity -----------------------------------------------------------
    name: str = "unnamed"
    family: str = "dense"  # dense | moe | ssm | hybrid | encdec | vlm | audio
    source: str = ""       # citation string from the assignment table

    # -- trunk dimensions ---------------------------------------------------
    num_layers: int = 2
    d_model: int = 128
    num_heads: int = 2
    num_kv_heads: int = 2
    head_dim: int = 0            # 0 -> d_model // num_heads
    d_ff: int = 256
    vocab_size: int = 512

    # -- layer stack --------------------------------------------------------
    block_pattern: Tuple[str, ...] = ("attn",)

    # -- attention ----------------------------------------------------------
    qkv_bias: bool = False       # Qwen1.5-style bias on Q/K/V projections
    rope_theta: float = 10_000.0
    sliding_window: int = 0      # 0 -> global attention; used by local_attn
    logit_softcap: float = 0.0   # tanh soft-capping (gemma-style); 0 = off

    # -- MLP / MoE ----------------------------------------------------------
    mlp_act: str = "silu"        # silu (SwiGLU) | gelu (GeGLU) | relu2 (Nemotron)
    mlp_gated: bool = True       # False -> classic 2-matrix FFN
    parallel_block: bool = False  # Cohere/GPT-J style: x + attn(h) + mlp(h)
    num_experts: int = 0         # 0 -> dense MLP
    num_experts_per_tok: int = 0
    moe_capacity_factor: float = 1.25
    num_shared_experts: int = 0  # DeepSeek/Moonlight-style always-on experts

    # -- recurrent (rglru / xlstm) -----------------------------------------
    rec_heads: int = 0           # heads for recurrent cells (0 -> num_heads)
    rglru_conv_width: int = 4    # temporal conv in the Griffin recurrent block
    lru_width: int = 0           # 0 -> d_model
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 4.0 / 3.0
    recurrent_chunk: int = 256   # chunked-scan length for train/prefill

    # -- encoder-decoder ----------------------------------------------------
    num_encoder_layers: int = 0  # >0 -> enc-dec model (seamless-m4t)
    encoder_d_ff: int = 0        # 0 -> d_ff

    # -- multimodal stubs ---------------------------------------------------
    num_vision_tokens: int = 0   # llava: patch embeddings prepended to seq
    audio_frontend: bool = False # seamless: encoder input is frame embeddings

    # -- embedding / misc ---------------------------------------------------
    tie_embeddings: bool = True
    emb_scale: bool = False      # multiply embeddings by sqrt(d_model)
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"

    # ------------------------------------------------------------------ api
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def resolved_lru_width(self) -> int:
        return self.lru_width or self.d_model

    @property
    def resolved_rec_heads(self) -> int:
        return self.rec_heads or self.num_heads

    @property
    def is_encdec(self) -> bool:
        return self.num_encoder_layers > 0

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    def blocks(self) -> Tuple[str, ...]:
        """The concrete per-layer block kinds, pattern tiled to num_layers."""
        pat = self.block_pattern
        reps = math.ceil(self.num_layers / len(pat))
        return tuple((pat * reps)[: self.num_layers])

    def layer_groups(self) -> Tuple[int, int]:
        """(n_full_groups, n_remainder_layers) for scan-over-groups."""
        plen = len(self.block_pattern)
        return self.num_layers // plen, self.num_layers % plen

    def validate(self) -> "ModelConfig":
        assert self.num_heads % self.num_kv_heads == 0, (
            f"{self.name}: num_heads={self.num_heads} not divisible by "
            f"num_kv_heads={self.num_kv_heads}"
        )
        for kind in self.block_pattern:
            assert kind in BLOCK_KINDS, f"{self.name}: unknown block {kind!r}"
        if self.is_moe:
            assert self.num_experts_per_tok > 0
        if "local_attn" in self.block_pattern:
            assert self.sliding_window > 0, f"{self.name}: local_attn needs window"
        return self

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw).validate()

    # Does every attention block have bounded (sub-quadratic) context?
    @property
    def subquadratic(self) -> bool:
        blocks = set(self.blocks()) - {"ffn"}
        if "attn" in blocks:
            return False
        if "local_attn" in blocks:
            return self.sliding_window > 0
        return True  # pure recurrent


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned (workload) input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode
    microbatches: int = 1  # gradient-accumulation splits for train


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
