"""Mixture-of-experts FFN: tokens-choose top-k routing with sort-based
capacity dispatch (TPU-native: no ragged tensors, one argsort + scatter/
gather, expert dimension sharded on the `model` mesh axis so XLA emits the
all-to-all).

Supports Moonlight-style shared experts (always-on dense branch) and
Qwen3-MoE-style normalized top-k gates.
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import Maker, act_fn, shard
from repro.models.mlp import apply_mlp, make_mlp
from repro.sharding import rules as rules_lib


def capacity(num_tokens: int, num_experts: int, k: int, factor: float) -> int:
    cap = int(math.ceil(num_tokens * k * factor / num_experts))
    # round up to a lane-friendly multiple; keep >= k so tiny tests route
    return max(k, ((cap + 7) // 8) * 8)


def make_moe(mk: Maker, cfg: ModelConfig) -> Dict:
    d, e, ff = cfg.d_model, cfg.num_experts, cfg.d_ff
    p = {
        "router": mk.normal((d, e), ("embed", "experts"), scale=1.0 / math.sqrt(d)),
        "wg": mk.normal((e, d, ff), ("experts", "embed", "expert_ffn")),
        "wu": mk.normal((e, d, ff), ("experts", "embed", "expert_ffn")),
        "wd": mk.normal((e, ff, d), ("experts", "expert_ffn", "embed"),
                        scale=1.0 / math.sqrt(ff)),
    }
    if cfg.num_shared_experts > 0:
        p["shared"] = make_mlp(mk.fork(), d, ff * cfg.num_shared_experts)
    return p


def route(
    logits: jax.Array, k: int, normalize: bool = True
) -> Tuple[jax.Array, jax.Array]:
    """Top-k routing.  logits (T, E) -> weights (T, k), expert ids (T, k)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    weights, idx = jax.lax.top_k(probs, k)
    if normalize:
        weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    return weights, idx


def apply_moe(p: Dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """x: (B, S, d) -> (B, S, d)."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    T = B * S
    xf = x.reshape(T, d)

    logits = jnp.einsum("td,de->te", xf, p["router"],
                        preferred_element_type=jnp.float32)
    weights, idx = route(logits, k)  # (T, k) each

    # --- sort-based position-in-expert --------------------------------------
    e_flat = idx.reshape(-1)                               # (T*k,)
    order = jnp.argsort(e_flat)                            # stable
    e_sorted = e_flat[order]
    counts = jnp.zeros((E,), jnp.int32).at[e_sorted].add(1)
    starts = jnp.cumsum(counts) - counts                   # (E,)
    pos_sorted = jnp.arange(T * k, dtype=jnp.int32) - starts[e_sorted]
    pos_flat = jnp.zeros((T * k,), jnp.int32).at[order].set(pos_sorted)

    C = capacity(T, E, k, cfg.moe_capacity_factor)
    keep = pos_flat < C
    slot = jnp.where(keep, pos_flat, C)                    # C = drop bin

    # --- dispatch: scatter tokens into (E, C, d) ----------------------------
    t_flat = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    xd = xf[t_flat]                                        # (T*k, d)
    buf = jnp.zeros((E, C, d), x.dtype)
    buf = buf.at[e_flat, slot].add(
        jnp.where(keep[:, None], xd, 0), mode="drop"
    )
    buf = shard(buf, "experts", None, None)

    # --- expert FFN ----------------------------------------------------------
    g = jnp.einsum("ecd,edf->ecf", buf, p["wg"])
    u = jnp.einsum("ecd,edf->ecf", buf, p["wu"])
    h = act_fn(cfg.mlp_act)(g) * u
    h = shard(h, "experts", None, None)
    y = jnp.einsum("ecf,efd->ecd", h, p["wd"])
    y = shard(y, "experts", None, None)

    # --- combine: gather back + weighted sum over k --------------------------
    yk = y.at[e_flat, slot].get(mode="fill", fill_value=0)  # (T*k, d)
    yk = jnp.where(keep[:, None], yk, 0)
    out = jnp.sum(
        yk.reshape(T, k, d) * weights[..., None].astype(x.dtype), axis=1
    )
    out = out.reshape(B, S, d)

    if "shared" in p:
        out = out + apply_mlp(p["shared"], x, cfg.mlp_act)
    return shard(out, "batch", None, "act_embed")


def _data_shards() -> int:
    mesh = rules_lib.current_mesh()
    if mesh is None:
        return 1
    n = 1
    for a in ("pod", "data"):
        n *= mesh.shape.get(a, 1)
    return n


def apply_moe_blocked(p: Dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Block-local MoE dispatch (EXPERIMENTS §Perf, qwen3-moe iteration 2).

    The naive global scatter into the expert-sharded (E, C, d) buffer lowers
    under SPMD as replicate+all-reduce of the whole buffer (~5 GB/layer/
    microbatch on qwen3-moe).  Here tokens are processed in one block per
    data shard: routing, position-in-expert, scatter, expert GEMMs and the
    combine-gather are all *batched over the block axis*, which SPMD keeps
    shard-local (token activations are model-axis-replicated already).  The
    only cross-device traffic left is the top-k combine all-reduce over the
    `model` axis — O(tokens x d), not O(E x C x d).

    Capacity is per block (= per data shard), matching how capacity behaves
    in real expert-parallel deployments.
    """
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    T = B * S
    D = _data_shards()
    if T % D or (B % D and D > 1):
        D = 1  # fallback: unsharded host runs / uneven batch
    Tl = T // D
    xf = x.reshape(D, Tl, d)
    xf = shard(xf, "batch", None, None)

    logits = jnp.einsum("xtd,de->xte", xf, p["router"],
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, k)           # (D, Tl, k)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)

    e_flat = idx.reshape(D, Tl * k)
    order = jnp.argsort(e_flat, axis=1)
    e_sorted = jnp.take_along_axis(e_flat, order, axis=1)
    counts = jax.vmap(
        lambda es: jnp.zeros((E,), jnp.int32).at[es].add(1))(e_sorted)
    starts = jnp.cumsum(counts, axis=1) - counts     # (D, E)
    pos_sorted = jnp.arange(Tl * k, dtype=jnp.int32)[None] -         jnp.take_along_axis(starts, e_sorted, axis=1)
    pos_flat = jax.vmap(
        lambda o, ps: jnp.zeros((Tl * k,), jnp.int32).at[o].set(ps)
    )(order, pos_sorted)

    C = capacity(Tl, E, k, cfg.moe_capacity_factor)
    keep = pos_flat < C
    slot = jnp.where(keep, pos_flat, C)

    t_flat = jnp.repeat(jnp.arange(Tl, dtype=jnp.int32), k)
    xd = jnp.take(xf, t_flat, axis=1)                # (D, Tl*k, d)
    xd = jnp.where(keep[..., None], xd, 0)

    def scatter_block(xb, eb, sb):
        return jnp.zeros((E, C, d), x.dtype).at[eb, sb].add(xb, mode="drop")

    buf = jax.vmap(scatter_block)(xd.astype(x.dtype), e_flat, slot)
    buf = shard(buf, "batch", "experts", None, None)

    g = jnp.einsum("xecd,edf->xecf", buf, p["wg"])
    u = jnp.einsum("xecd,edf->xecf", buf, p["wu"])
    h = act_fn(cfg.mlp_act)(g) * u
    y = jnp.einsum("xecf,efd->xecd", h, p["wd"])
    y = shard(y, "batch", "experts", None, None)

    yk = jax.vmap(lambda yb, eb, sb: yb.at[eb, sb].get(
        mode="fill", fill_value=0))(y, e_flat, slot)  # (D, Tl*k, d)
    yk = jnp.where(keep[..., None], yk, 0)
    out = jnp.sum(
        yk.reshape(D, Tl, k, d) * weights[..., None].astype(x.dtype), axis=2)
    out = out.reshape(B, S, d)

    if "shared" in p:
        out = out + apply_mlp(p["shared"], x, cfg.mlp_act)
    return shard(out, "batch", None, "act_embed")


def aux_load_balance_loss(logits: jax.Array, idx: jax.Array, E: int) -> jax.Array:
    """Switch-style load-balancing auxiliary loss (fraction * prob per expert)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)  # (T, E)
    one_hot = jax.nn.one_hot(idx[:, 0], E, dtype=jnp.float32)    # top-1 fraction
    frac = jnp.mean(one_hot, axis=0)
    prob = jnp.mean(probs, axis=0)
    return E * jnp.sum(frac * prob)
