"""Trace-time flags threaded through the model code.

``unroll_scans`` — replace ``lax.scan`` over layer groups (and the mLSTM
chunk scan) with unrolled loops.  XLA's cost analysis visits a ``while``
body once, so the multi-pod dry-run lowers an unrolled variant to extract
exact whole-program FLOPs (the scan variant is what actually compiles/runs).
"""

from __future__ import annotations

import contextlib
import threading


class _Flags(threading.local):
    def __init__(self):
        self.unroll_scans = False
        self.moe_blocked = False


_FLAGS = _Flags()


def unroll_scans() -> bool:
    return _FLAGS.unroll_scans


@contextlib.contextmanager
def use_unroll(value: bool = True):
    prev = _FLAGS.unroll_scans
    _FLAGS.unroll_scans = value
    try:
        yield
    finally:
        _FLAGS.unroll_scans = prev


def moe_blocked() -> bool:
    return _FLAGS.moe_blocked


@contextlib.contextmanager
def use_moe_blocked(value: bool = True):
    prev = _FLAGS.moe_blocked
    _FLAGS.moe_blocked = value
    try:
        yield
    finally:
        _FLAGS.moe_blocked = prev
