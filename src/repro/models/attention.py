"""Grouped-query attention (train / prefill / decode), sliding-window and
cross-attention variants.

The numeric core is routed through ``repro.kernels.dispatch`` so the Pallas
flash kernels can take over on TPU while the pure-jnp reference (which is the
kernels' oracle) runs everywhere else and is what the multi-pod dry-run
lowers (XLA cost analysis needs real HLO, not an opaque custom call).
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import dispatch
from repro.models import cache as cache_lib
from repro.models.config import ModelConfig
from repro.models.layers import Maker, P, apply_rope, shard

NEG_INF = -2.0 ** 30  # large-but-finite; avoids NaN from all-masked rows


def make_attention(mk: Maker, cfg: ModelConfig, cross: bool = False) -> Dict:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    p = {
        "wq": mk.normal((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": mk.normal((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": mk.normal((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": mk.normal((h, hd, d), ("heads", "head_dim", "embed"),
                        scale=1.0 / math.sqrt(h * hd)),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = mk.zeros((h, hd), ("heads", "head_dim"))
        p["bk"] = mk.zeros((kv, hd), ("kv_heads", "head_dim"))
        p["bv"] = mk.zeros((kv, hd), ("kv_heads", "head_dim"))
    return p


def _project_q(p, x, cfg: ModelConfig):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if "bq" in p:
        q = q + p["bq"]
    return shard(q, "batch", None, "act_heads", None)


def _project_kv(p, x, cfg: ModelConfig):
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bk" in p:
        k, v = k + p["bk"], v + p["bv"]
    return shard(k, "batch", None, "act_kv", None), shard(v, "batch", None, "act_kv", None)


def _out_proj(p, o):
    y = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return shard(y, "batch", None, "act_embed")


def sdpa(
    q: jax.Array,          # (B, S, Hq, D)
    k: jax.Array,          # (B, T, Hkv, D)
    v: jax.Array,          # (B, T, Hkv, D)
    *,
    q_positions: jax.Array,    # (B, S) int32
    k_positions: jax.Array,    # (B, T) int32; -1 marks invalid (unfilled) slots
    causal: bool,
    window: int = 0,
    softcap: float = 0.0,
) -> jax.Array:
    """Masked grouped-query attention, fp32 softmax.  Pure-jnp reference."""
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, S, Hkv, G, D)
    scores = jnp.einsum(
        "bshgd,bthd->bhgst", qg, k, preferred_element_type=jnp.float32
    ) / math.sqrt(D)
    if softcap > 0.0:
        scores = jnp.tanh(scores / softcap) * softcap
    valid = (k_positions >= 0)[:, None, None, None, :]
    if causal:
        valid = valid & (
            q_positions[:, None, None, :, None] >= k_positions[:, None, None, None, :]
        )
    if window > 0:
        valid = valid & (
            q_positions[:, None, None, :, None] - k_positions[:, None, None, None, :]
            < window
        )
    scores = jnp.where(valid, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhgst,bthd->bshgd", probs.astype(v.dtype), v)
    return o.reshape(B, S, Hq, D)


def apply_attention_train(
    p: Dict,
    x: jax.Array,
    cfg: ModelConfig,
    positions: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
) -> jax.Array:
    """Full-sequence attention (training / encoder / prefill math)."""
    q = _project_q(p, x, cfg)
    k, v = _project_kv(p, x, cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    o = dispatch.flash_attention(
        q, k, v,
        q_positions=positions, k_positions=positions,
        causal=causal, window=window, softcap=cfg.logit_softcap,
    )
    return _out_proj(p, o)


def apply_attention_prefill(
    p: Dict,
    x: jax.Array,
    cfg: ModelConfig,
    positions: jax.Array,
    kv_cache: Dict,
    *,
    window: int = 0,
    block_tables: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Dict]:
    """Causal attention over the prompt; returns output + filled KV cache.

    The attention math is layout-independent (the prompt is self-contained);
    only the cache write differs: paged entries (``kp`` in the dict) scatter
    K/V into pool blocks through ``block_tables``, contiguous/ring entries
    take the dense fill.
    """
    q = _project_q(p, x, cfg)
    k, v = _project_kv(p, x, cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    o = dispatch.flash_attention(
        q, k, v,
        q_positions=positions, k_positions=positions,
        causal=True, window=window, softcap=cfg.logit_softcap,
    )
    if "kp" in kv_cache:
        kv_cache = cache_lib.fill_paged_cache(kv_cache, k, v, positions,
                                              block_tables)
    else:
        kv_cache = cache_lib.fill_attn_cache(kv_cache, k, v, positions)
    return _out_proj(p, o), kv_cache


def apply_attention_prefill_chunk(
    p: Dict,
    x: jax.Array,            # (B, C, d) one prompt chunk
    cfg: ModelConfig,
    positions: jax.Array,    # (B, C) absolute positions start..start+C-1
    kv_cache: Dict,
    *,
    window: int = 0,
    block_tables: Optional[jax.Array] = None,
    valid: Optional[jax.Array] = None,
    overwrite_from: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Dict]:
    """Chunked prefill: the chunk attends to every cached chunk 0..N-1 plus
    itself (causally), then its K/V is appended for chunks N+1.. and decode.

    Contiguous/ring caches attend over (cache-before-append ++ chunk) so a
    chunk longer than a sliding window still sees its own early keys (the
    ring would evict them during the append).  Paged caches append first
    and attend over the gathered pool, where index == absolute position.

    ``valid`` (B, C) bool marks each row's real tokens when ragged per-slot
    chunks are packed into one static-width batch (the unified mixed step):
    pad columns write nothing (paged: routed to the garbage block, whose
    logical positions are acausal; contiguous: key positions forced to -1)
    and their query outputs are garbage the caller discards.

    ``overwrite_from`` (B,) int32, when given, hides *cached* contiguous
    entries at positions >= the row's value from the attention read.  The
    speculative verify step re-writes positions its previous window already
    wrote (rejected draft suffixes are never physically rolled back): the
    stale entries share the chunk's own positions, and without the mask the
    contiguous branch — which attends over cache-before-append ++ chunk —
    would both attend to garbage and double-count the overlap.  The paged
    branch needs no mask: it appends *first*, so the overlap is overwritten
    in the pool before the gather, and stale positions beyond the window
    exceed every query position (causal masking hides them).
    """
    q = _project_q(p, x, cfg)
    k_new, v_new = _project_kv(p, x, cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k_new = apply_rope(k_new, positions, cfg.rope_theta)
    if "kp" in kv_cache:
        kv_cache = cache_lib.append_paged_cache(
            kv_cache, k_new, v_new, positions, block_tables, valid)
        k_all, v_all, k_pos = cache_lib.gather_paged_kv(kv_cache, block_tables)
        o = dispatch.flash_attention(
            q, k_all, v_all, q_positions=positions, k_positions=k_pos,
            causal=True, window=window, softcap=cfg.logit_softcap,
        )
        return _out_proj(p, o), kv_cache
    k_all = jnp.concatenate([kv_cache["k"].astype(k_new.dtype), k_new], axis=1)
    v_all = jnp.concatenate([kv_cache["v"].astype(v_new.dtype), v_new], axis=1)
    chunk_pos = positions if valid is None else jnp.where(valid, positions, -1)
    cache_pos = kv_cache["pos"]
    if overwrite_from is not None:
        cache_pos = jnp.where(
            cache_pos >= overwrite_from[:, None], -1, cache_pos)
    k_pos = jnp.concatenate([cache_pos, chunk_pos], axis=1)
    o = dispatch.flash_attention(
        q, k_all, v_all, q_positions=positions, k_positions=k_pos,
        causal=True, window=window, softcap=cfg.logit_softcap,
    )
    kv_cache = cache_lib.append_attn_cache(kv_cache, k_new, v_new, positions,
                                           valid)
    return _out_proj(p, o), kv_cache


def apply_attention_decode(
    p: Dict,
    x: jax.Array,            # (B, 1, d)
    cfg: ModelConfig,
    positions: jax.Array,    # scalar or (B,) int32: index of the new token
    kv_cache: Dict,
    *,
    window: int = 0,
    block_tables: Optional[jax.Array] = None,
    update_mask: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Dict]:
    B = x.shape[0]
    positions = jnp.broadcast_to(jnp.asarray(positions, jnp.int32), (B,))
    pos_b = positions[:, None]
    q = _project_q(p, x, cfg)
    k_new, v_new = _project_kv(p, x, cfg)
    q = apply_rope(q, pos_b, cfg.rope_theta)
    k_new = apply_rope(k_new, pos_b, cfg.rope_theta)
    if "kp" in kv_cache:  # paged: append via block table, attend on the pool
        kv_cache = cache_lib.update_paged_cache(
            kv_cache, k_new, v_new, positions, block_tables, update_mask)
        o = dispatch.paged_decode_attention(
            q, kv_cache["kp"], kv_cache["vp"],
            block_tables=block_tables, q_positions=pos_b,
            window=window, softcap=cfg.logit_softcap,
        )
        return _out_proj(p, o), kv_cache
    kv_cache = cache_lib.update_attn_cache(kv_cache, k_new, v_new, positions,
                                           update_mask)
    o = dispatch.decode_attention(
        q, kv_cache["k"], kv_cache["v"],
        q_positions=pos_b, k_positions=kv_cache["pos"],
        window=window, softcap=cfg.logit_softcap,
    )
    return _out_proj(p, o), kv_cache


# -- cross attention (encoder-decoder) --------------------------------------

def apply_cross_attention(
    p: Dict,
    x: jax.Array,              # (B, S, d) decoder states
    cfg: ModelConfig,
    memory_kv: Tuple[jax.Array, jax.Array],  # precomputed (B, T, Hkv, D) pair
    memory_valid: Optional[jax.Array] = None,
) -> jax.Array:
    q = _project_q(p, x, cfg)
    k, v = memory_kv
    B, S = x.shape[:2]
    T = k.shape[1]
    q_pos = jnp.zeros((B, S), jnp.int32)
    k_pos = jnp.zeros((B, T), jnp.int32) if memory_valid is None else jnp.where(
        memory_valid, 0, -1
    )
    o = sdpa(q, k, v, q_positions=q_pos, k_positions=k_pos, causal=False)
    return _out_proj(p, o)


def precompute_cross_kv(p: Dict, memory: jax.Array, cfg: ModelConfig):
    """Project encoder memory to K/V once (reused across decode steps)."""
    return _project_kv(p, memory, cfg)
