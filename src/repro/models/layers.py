"""Parameter construction + elementary layers (pure JAX, no flax).

Parameters are nested dicts of ``jax.Array``.  During construction each leaf
is created through a ``Maker``, which records the *logical sharding axes* of
every parameter in a parallel tree.  ``split_params`` separates the two so
callers get ``(params, axes_tree)`` — the axes tree feeds
``sharding.rules.tree_pspecs`` to produce in_shardings for pjit.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.sharding.rules import shard


@dataclasses.dataclass
class P:
    """Temporary param leaf: value + logical axes (split off after init)."""

    value: jax.Array
    axes: Tuple[Optional[str], ...]


def _is_p(x) -> bool:
    return isinstance(x, P)


def split_params(tree) -> Tuple[Any, Any]:
    params = jax.tree.map(lambda p: p.value, tree, is_leaf=_is_p)
    axes = jax.tree.map(lambda p: p.axes, tree, is_leaf=_is_p)
    return params, axes


class Maker:
    """Splittable PRNG + initializer helper."""

    def __init__(self, key: jax.Array, dtype: jnp.dtype):
        self._key = key
        self.dtype = dtype

    def fork(self) -> "Maker":
        self._key, sub = jax.random.split(self._key)
        return Maker(sub, self.dtype)

    def _next(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    def normal(self, shape, axes, scale: Optional[float] = None) -> P:
        if scale is None:  # fan-in scaling on the first (input) dim
            scale = 1.0 / math.sqrt(max(1, shape[0]))
        v = jax.random.normal(self._next(), shape, jnp.float32) * scale
        return P(v.astype(self.dtype), tuple(axes))

    def zeros(self, shape, axes) -> P:
        return P(jnp.zeros(shape, self.dtype), tuple(axes))

    def ones(self, shape, axes) -> P:
        return P(jnp.ones(shape, self.dtype), tuple(axes))

    def const(self, value: jax.Array, axes) -> P:
        return P(value.astype(self.dtype), tuple(axes))


# --------------------------------------------------------------------------
# elementary ops
# --------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x, scale, bias, eps: float = 1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def make_norm(mk: Maker, d: int) -> Dict[str, P]:
    return {"scale": mk.zeros((d,), ("act_embed",))}


def apply_norm(p, x, eps: float = 1e-6):
    return rms_norm(x, p["scale"], eps)


# -- rotary embeddings ------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)  # (head_dim/2,)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D) with positions (..., S) broadcastable."""
    freqs = rope_freqs(x.shape[-1], theta)  # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -- activations ------------------------------------------------------------

def act_fn(name: str):
    return {
        "silu": jax.nn.silu,
        "gelu": jax.nn.gelu,
        "relu": jax.nn.relu,
        "relu2": lambda x: jnp.square(jax.nn.relu(x)),  # Primer / Nemotron
    }[name]


# -- embedding --------------------------------------------------------------

def make_embedding(mk: Maker, vocab: int, d: int) -> Dict[str, P]:
    return {"table": mk.normal((vocab, d), ("vocab", "embed"), scale=1.0)}


def embed_tokens(p, tokens: jax.Array, scale: bool, d_model: int) -> jax.Array:
    x = jnp.take(p["table"], tokens, axis=0)
    if scale:
        x = x * jnp.asarray(math.sqrt(d_model), x.dtype)
    return shard(x, "batch", None, "act_embed")


def unembed(p, x: jax.Array, softcap: float = 0.0) -> jax.Array:
    logits = jnp.einsum(
        "...d,vd->...v", x, p["table"], preferred_element_type=jnp.float32
    )
    if softcap > 0.0:
        logits = jnp.tanh(logits / softcap) * softcap
    return shard(logits, "batch", None, "vocab_out")
