"""MLP blocks: gated (SwiGLU/GeGLU) and classic 2-matrix (ReLU²/ReLU) FFNs."""

from __future__ import annotations

import math
from typing import Dict

import jax.numpy as jnp

from repro.models.layers import Maker, act_fn, shard


def make_mlp(mk: Maker, d: int, d_ff: int, gated: bool = True) -> Dict:
    p = {
        "wg": mk.normal((d, d_ff), ("embed", "ffn")),
        "wd": mk.normal((d_ff, d), ("ffn", "embed"), scale=1.0 / math.sqrt(d_ff)),
    }
    if gated:
        p["wu"] = mk.normal((d, d_ff), ("embed", "ffn"))
    return p


def apply_mlp(p: Dict, x, act: str = "silu"):
    g = jnp.einsum("bsd,df->bsf", x, p["wg"])
    h = act_fn(act)(g)
    if "wu" in p:
        h = h * jnp.einsum("bsd,df->bsf", x, p["wu"])
    h = shard(h, "batch", None, "act_ffn")
    y = jnp.einsum("bsf,fd->bsd", h, p["wd"])
    return shard(y, "batch", None, "act_embed")
