"""Decode-time caches: full KV, paged (block-pool) KV, ring-buffer (sliding
window) KV, recurrent state, and cross-attention memory.

A cache entry is a plain dict of arrays so the whole cache is a pytree that
rides through ``jax.jit`` / ``lax.scan``.  Two layouts for full-context
attention KV:

* **contiguous** — per-slot ``(batch, max_len, H, D)`` regions with explicit
  absolute key positions (``pos``; -1 = unfilled), which makes ring buffers,
  masking, and RoPE-at-write-time uniform across cache kinds.
* **paged** — a global block pool ``kp``/``vp`` of shape ``(num_blocks,
  block_size, H, D)`` shared by every slot, addressed through an int32
  block table ``(batch, max_blocks_per_slot)``.  Token at absolute position
  ``p`` of slot ``s`` lives at ``pool[table[s, p // bs], p % bs]``, so a
  slot only consumes the blocks its actual length needs instead of a
  worst-case ``max_len`` stripe.  Block 0 is a reserved garbage block:
  idle slots keep writing their frozen token there (static-shape decode),
  and freed slots point their whole table row back at it.  No ``pos``
  array is needed — gathered key index ``j`` *is* absolute position ``j``,
  and causal masking hides everything past the slot's length.

Sliding-window (``local_attn``) caches keep the ring layout in both modes:
their memory is already bounded by the window, so paging buys nothing.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

GARBAGE_BLOCK = 0  # pool block reserved for idle-slot writes; never allocated


def blocks_per_slot(max_len: int, block_size: int) -> int:
    """Block-table width needed to address ``max_len`` tokens."""
    return -(-max_len // block_size)


def default_num_blocks(batch: int, max_len: int, block_size: int) -> int:
    """Worst-case pool: every slot full, plus the reserved garbage block."""
    return batch * blocks_per_slot(max_len, block_size) + 1


def init_attn_cache(
    batch: int,
    max_len: int,
    n_kv: int,
    head_dim: int,
    dtype,
    window: int = 0,
) -> Dict:
    length = min(window, max_len) if window > 0 else max_len
    return {
        "k": jnp.zeros((batch, length, n_kv, head_dim), dtype),
        "v": jnp.zeros((batch, length, n_kv, head_dim), dtype),
        "pos": jnp.full((batch, length), -1, jnp.int32),  # per-row positions
        "ring": jnp.asarray(1 if (window > 0 and window < max_len) else 0, jnp.int32),
    }


def fill_attn_cache(cache: Dict, k: jax.Array, v: jax.Array, positions: jax.Array) -> Dict:
    """Write a full prefill's K/V (B, S, H, D) into the cache.

    For ring caches only the last ``L`` timesteps are kept.  ``positions`` is
    (B, S) but all rows are identical in the batched-serving setting; row 0 is
    used for the slot bookkeeping.
    """
    B, S = k.shape[:2]
    L = cache["k"].shape[1]
    pos_row = positions[0].astype(jnp.int32)
    if S >= L:
        k_tail, v_tail, p_tail = k[:, S - L:], v[:, S - L:], pos_row[S - L:]
    else:
        pad = L - S
        k_tail = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_tail = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        p_tail = jnp.pad(pos_row, (0, pad), constant_values=-1)
    slots = jnp.where(p_tail >= 0, p_tail % L, jnp.arange(L) % L)
    k_new = cache["k"].at[:, slots].set(k_tail)
    v_new = cache["v"].at[:, slots].set(v_tail)
    pos_new = cache["pos"].at[:, slots].set(p_tail[None, :])
    return {"k": k_new, "v": v_new, "pos": pos_new, "ring": cache["ring"]}


def update_attn_cache(cache: Dict, k_new: jax.Array, v_new: jax.Array,
                      positions: jax.Array,
                      update_mask: jax.Array = None) -> Dict:
    """Write one decoded token's K/V (B, 1, H, D) at per-row ``positions`` (B,).

    ``update_mask`` (B,) bool, when given, turns masked-off rows into no-op
    writes (the current cache content is written back).  The fused serving
    step uses it so idle and mid-prefill slots never clobber ring entries
    that a chunked prefill is concurrently filling.
    """
    B, L = cache["pos"].shape
    positions = jnp.broadcast_to(jnp.asarray(positions, jnp.int32), (B,))
    slot = positions % L
    rows = jnp.arange(B)
    k_w, v_w, p_w = k_new[:, 0], v_new[:, 0], positions
    if update_mask is not None:
        m = update_mask.reshape(B, 1, 1)
        k_w = jnp.where(m, k_w, cache["k"][rows, slot])
        v_w = jnp.where(m, v_w, cache["v"][rows, slot])
        p_w = jnp.where(update_mask, p_w, cache["pos"][rows, slot])
    k = cache["k"].at[rows, slot].set(k_w)
    v = cache["v"].at[rows, slot].set(v_w)
    pos = cache["pos"].at[rows, slot].set(p_w)
    return {"k": k, "v": v, "pos": pos, "ring": cache["ring"]}


def append_attn_cache(cache: Dict, k: jax.Array, v: jax.Array,
                      positions: jax.Array) -> Dict:
    """Write a prompt chunk's K/V (B, C, H, D) at absolute ``positions``
    (B, C) into a contiguous or ring cache, preserving existing entries.

    Unlike ``fill_attn_cache`` (whole-prompt, fresh cache) this scatters
    only the chunk's own C columns, so chunk N lands next to chunks
    0..N-1.  A chunk longer than a ring keeps its tail (earlier chunk
    positions would be evicted immediately anyway)."""
    B, C = k.shape[:2]
    L = cache["k"].shape[1]
    if C > L:  # ring shorter than the chunk: only the tail survives
        k, v, positions = k[:, C - L:], v[:, C - L:], positions[:, C - L:]
        C = L
    rows = jnp.arange(B)[:, None]
    slots = positions % L
    return {
        "k": cache["k"].at[rows, slots].set(k.astype(cache["k"].dtype)),
        "v": cache["v"].at[rows, slots].set(v.astype(cache["v"].dtype)),
        "pos": cache["pos"].at[rows, slots].set(positions),
        "ring": cache["ring"],
    }


# -- paged (block-pool) attention cache --------------------------------------

def init_paged_attn_cache(
    num_blocks: int, block_size: int, n_kv: int, head_dim: int, dtype
) -> Dict:
    return {
        "kp": jnp.zeros((num_blocks, block_size, n_kv, head_dim), dtype),
        "vp": jnp.zeros((num_blocks, block_size, n_kv, head_dim), dtype),
    }


def fill_paged_cache(
    cache: Dict, k: jax.Array, v: jax.Array, positions: jax.Array,
    block_tables: jax.Array,
) -> Dict:
    """Scatter a full prefill's K/V (B, S, H, D) into pool blocks.

    The prompt occupies absolute positions 0..S-1, so row ``b`` fills table
    entries ``0..ceil(S/bs)-1`` of ``block_tables[b]`` in order.  S is
    padded up to a whole number of blocks; the pad tail lands at positions
    >= S inside the last block and is hidden by causal masking.
    """
    del positions  # prompt positions are 0..S-1 by construction
    B, S = k.shape[:2]
    bs = cache["kp"].shape[1]
    nb = -(-S // bs)
    pad = nb * bs - S
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    idx = block_tables[:, :nb].reshape(-1)
    kb = k.reshape(B * nb, bs, *k.shape[2:]).astype(cache["kp"].dtype)
    vb = v.reshape(B * nb, bs, *v.shape[2:]).astype(cache["vp"].dtype)
    return {"kp": cache["kp"].at[idx].set(kb), "vp": cache["vp"].at[idx].set(vb)}


def update_paged_cache(
    cache: Dict, k_new: jax.Array, v_new: jax.Array, positions: jax.Array,
    block_tables: jax.Array, update_mask: jax.Array = None,
) -> Dict:
    """Write one decoded token's K/V (B, 1, H, D) at per-row ``positions``.

    Active slots always have the covering block allocated (admission
    reserves blocks for prompt + budget); idle slots' tables point at the
    garbage block, so their static-shape writes land in trash.
    ``update_mask`` (B,) bool additionally routes masked-off rows to the
    garbage block regardless of their table row — the engine arms a slot's
    real table row when it becomes decode-eligible, and only the chunked
    prefill may write its blocks before that.
    """
    B = block_tables.shape[0]
    positions = jnp.broadcast_to(jnp.asarray(positions, jnp.int32), (B,))
    bs = cache["kp"].shape[1]
    rows = jnp.arange(B)
    blk = block_tables[rows, positions // bs]
    if update_mask is not None:
        blk = jnp.where(update_mask, blk, GARBAGE_BLOCK)
    off = positions % bs
    kp = cache["kp"].at[blk, off].set(k_new[:, 0].astype(cache["kp"].dtype))
    vp = cache["vp"].at[blk, off].set(v_new[:, 0].astype(cache["vp"].dtype))
    return {"kp": kp, "vp": vp}


def append_paged_cache(
    cache: Dict, k: jax.Array, v: jax.Array, positions: jax.Array,
    block_tables: jax.Array,
) -> Dict:
    """Scatter a prompt chunk's K/V (B, C, H, D) at absolute ``positions``
    (B, C) into pool blocks through the block tables.

    Unlike ``fill_paged_cache`` (whole prompt, block-aligned from position
    0) the chunk may start and end anywhere inside a block, so each token
    is routed individually: position ``p`` lands at
    ``pool[table[b, p // bs], p % bs]``."""
    bs = cache["kp"].shape[1]
    blk = jnp.take_along_axis(block_tables, positions // bs, axis=1)  # (B, C)
    off = positions % bs
    kp = cache["kp"].at[blk, off].set(k.astype(cache["kp"].dtype))
    vp = cache["vp"].at[blk, off].set(v.astype(cache["vp"].dtype))
    return {"kp": kp, "vp": vp}


def gather_paged_kv(cache: Dict, block_tables: jax.Array):
    """Materialize each row's pool blocks as dense K/V plus key positions.

    Returns ``(k, v, k_positions)`` of shapes (B, M*bs, H, D) x2 and
    (B, M*bs) where M is the block-table width.  Gathered index ``j`` *is*
    absolute position ``j``; table entries beyond the row's allocation
    point at the garbage block, whose logical positions exceed every
    prompt position and are hidden by causal masking.  Used by the chunked
    prefill (chunk N attends to cached chunks 0..N-1 plus itself);
    decode-side reads go through the scalar-prefetch Pallas kernel
    instead, which never materializes this gather."""
    kp, vp = cache["kp"], cache["vp"]
    B, M = block_tables.shape
    bs = kp.shape[1]
    k = kp[block_tables].reshape(B, M * bs, *kp.shape[2:])
    v = vp[block_tables].reshape(B, M * bs, *vp.shape[2:])
    pos = jnp.broadcast_to(jnp.arange(M * bs, dtype=jnp.int32)[None], (B, M * bs))
    return k, v, pos


# -- recurrent states --------------------------------------------------------

def init_rglru_state(batch: int, width: int, conv_width: int, dtype) -> Dict:
    return {
        "h": jnp.zeros((batch, width), jnp.float32),
        "conv": jnp.zeros((batch, conv_width - 1, width), dtype),
    }


def init_mlstm_state(
    batch: int, heads: int, dk: int, dv: int, conv_width: int = 0, dtype=jnp.float32
) -> Dict:
    st = {
        "C": jnp.zeros((batch, heads, dk, dv), jnp.float32),
        "n": jnp.zeros((batch, heads, dk), jnp.float32),
        "m": jnp.full((batch, heads), -1e30, jnp.float32),
    }
    if conv_width > 0:
        st["conv"] = jnp.zeros((batch, conv_width - 1, heads * dv), dtype)
    return st


def init_slstm_state(batch: int, heads: int, dh: int, conv_width: int, dtype) -> Dict:
    return {
        "c": jnp.zeros((batch, heads, dh), jnp.float32),
        "n": jnp.zeros((batch, heads, dh), jnp.float32),
        "m": jnp.full((batch, heads, dh), -1e30, jnp.float32),
        "h": jnp.zeros((batch, heads, dh), jnp.float32),
        "conv": jnp.zeros((batch, conv_width - 1, heads * dh), dtype),
    }


# -- per-block cache constructors -------------------------------------------

def init_block_cache(
    cfg: ModelConfig, kind: str, batch: int, max_len: int, dtype,
    *, layout: str = "contiguous", block_size: int = 16,
    num_blocks: int = 0,
) -> Dict:
    hd = cfg.resolved_head_dim
    if kind == "ffn":
        return {}
    if kind == "attn":
        if layout == "paged":
            n = num_blocks or default_num_blocks(batch, max_len, block_size)
            return init_paged_attn_cache(n, block_size, cfg.num_kv_heads, hd, dtype)
        return init_attn_cache(batch, max_len, cfg.num_kv_heads, hd, dtype)
    if kind == "local_attn":
        return init_attn_cache(
            batch, max_len, cfg.num_kv_heads, hd, dtype, window=cfg.sliding_window
        )
    if kind == "rglru":
        return init_rglru_state(
            batch, cfg.resolved_lru_width, cfg.rglru_conv_width, dtype
        )
    if kind == "mlstm":
        w = int(cfg.d_model * cfg.mlstm_proj_factor)
        h = cfg.resolved_rec_heads
        return init_mlstm_state(batch, h, w // h, w // h, cfg.rglru_conv_width, dtype)
    if kind == "slstm":
        h = cfg.resolved_rec_heads
        return init_slstm_state(batch, h, cfg.d_model // h, cfg.rglru_conv_width, dtype)
    raise ValueError(f"no cache for block kind {kind!r}")


def cache_bytes(cache) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))
