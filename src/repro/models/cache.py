"""Decode-time caches: full KV, paged (block-pool) KV, ring-buffer (sliding
window) KV, recurrent state, and cross-attention memory.

A cache entry is a plain dict of arrays so the whole cache is a pytree that
rides through ``jax.jit`` / ``lax.scan``.  Two layouts for full-context
attention KV:

* **contiguous** — per-slot ``(batch, max_len, H, D)`` regions with explicit
  absolute key positions (``pos``; -1 = unfilled), which makes ring buffers,
  masking, and RoPE-at-write-time uniform across cache kinds.
* **paged** — a global block pool ``kp``/``vp`` of shape ``(num_blocks,
  block_size, H, D)`` shared by every slot, addressed through an int32
  block table ``(batch, max_blocks_per_slot)``.  Token at absolute position
  ``p`` of slot ``s`` lives at ``pool[table[s, p // bs], p % bs]``, so a
  slot only consumes the blocks its actual length needs instead of a
  worst-case ``max_len`` stripe.  Block 0 is a reserved garbage block:
  idle slots keep writing their frozen token there (static-shape decode),
  and freed slots point their whole table row back at it.  No ``pos``
  array is needed — gathered key index ``j`` *is* absolute position ``j``,
  and causal masking hides everything past the slot's length.

Sliding-window (``local_attn``) caches keep the ring layout in both modes:
their memory is already bounded by the window, so paging buys nothing.
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

GARBAGE_BLOCK = 0  # pool block reserved for idle-slot writes; never allocated


def blocks_per_slot(max_len: int, block_size: int) -> int:
    """Block-table width needed to address ``max_len`` tokens."""
    return -(-max_len // block_size)


def default_num_blocks(batch: int, max_len: int, block_size: int) -> int:
    """Worst-case pool: every slot full, plus the reserved garbage block."""
    return batch * blocks_per_slot(max_len, block_size) + 1


def suggest_num_blocks(
    seq_lens, block_size: int, max_len: int, max_batch: int,
    concurrency: int = 0, q: float = 95.0,
) -> int:
    """Workload-sized pool suggestion (``--kv-num-blocks auto``).

    Instead of the worst case (every slot at ``max_len``), size the pool
    for the observed load: the ``q``-th percentile of the trace's total
    sequence lengths (prompt + decode budget, clamped to ``max_len``)
    times the expected number of concurrently live slots, plus one slack
    block per slot (bucketing / partial-tail rounding) and the reserved
    garbage block.  ``concurrency`` defaults to ``max_batch`` (the
    saturated case — exactly when pool sizing matters); pass an estimate
    from the trace (``serving.workload.estimate_concurrency``) for lighter
    open-loop load.

    The suggestion is clamped to ``[one worst-case request + garbage,
    worst case]``: below the floor a single long request could never
    finish, and above the ceiling the extra blocks are unreachable.  A
    pool sized this way can still overcommit on a bursty tail — pair it
    with ``preemption="recompute"`` so pressure preempts instead of
    failing.
    """
    lens = sorted(min(int(n), max_len) for n in seq_lens)
    if not lens:
        return default_num_blocks(max_batch, max_len, block_size)
    k = max(int(-(-len(lens) * q // 100)), 1) - 1
    p_len = lens[min(k, len(lens) - 1)]
    slots = min(max(int(concurrency) or max_batch, 1), max_batch)
    want = slots * (blocks_per_slot(p_len, block_size) + 1) + 1
    floor = blocks_per_slot(max_len, block_size) + 1
    return min(max(want, floor), default_num_blocks(max_batch, max_len, block_size))


# -- host-side block-pool bookkeeping (paged layout) -------------------------

_FNV_OFFSET = 0xCBF29CE484222325
_FNV_PRIME = 0x100000001B3
_MASK64 = (1 << 64) - 1


def hash_token_blocks(tokens, block_size: int) -> List[int]:
    """Chained FNV-1a hash per *full* ``block_size`` block of ``tokens``.

    ``hashes[i]`` covers ``tokens[0 : (i+1) * block_size]`` — block ``i``'s
    hash folds in block ``i-1``'s, so a match at index ``i`` implies (up to
    hash collision) the whole token prefix matches, and with it the K/V
    content of pool blocks ``0..i`` (the prompt occupies absolute positions
    from 0, so block index determines the RoPE positions baked into the
    keys).  A trailing partial block is not hashed: it is still being
    written to (by the rest of the prompt or by decode) and must never be
    shared."""
    hashes: List[int] = []
    h = _FNV_OFFSET
    for i in range(len(tokens) // block_size):
        for t in tokens[i * block_size:(i + 1) * block_size]:
            h = ((h ^ (int(t) & _MASK64)) * _FNV_PRIME) & _MASK64
        hashes.append(h)
    return hashes


class BlockPool:
    """Host-side bookkeeping for the paged KV block pool: the LIFO free
    stack, plus — for block-level prefix caching — per-block refcounts, the
    ``hash -> block`` registry, and an LRU pool of evictable cached blocks.

    A block's lifecycle::

        free stack --allocate--> private (owned by one request)
          private --register--> shared (refcount = live readers)
          shared --lookup hit--> refcount += 1 (another reader)
          shared --freed by last reader--> evictable LRU (content intact)
          evictable --lookup hit--> shared again (refcount 1)
          evictable --pool pressure--> evicted: unregistered, reallocated
          private --freed--> free stack

    Blocks never sit in two places: ``free_stack``, ``evictable``, and the
    engine's live slot tables partition blocks ``1..num_blocks-1`` (block 0
    is the reserved garbage block).  A registered block becomes visible to
    ``lookup`` only once ``mark_ready`` confirms its K/V was fully written
    (a chunked prefill registers at admission but fills over many steps).
    """

    def __init__(self, num_blocks: int):
        self.num_blocks = num_blocks
        # LIFO free stack over blocks 1..N-1 (0 = reserved garbage block)
        self.free_stack: List[int] = list(range(num_blocks - 1, 0, -1))
        self.refs: Dict[int, int] = {}        # registered block -> live readers
        self.block_of: Dict[int, int] = {}    # prefix hash -> block id
        self.hash_of: Dict[int, int] = {}     # block id -> prefix hash
        self.ready: set = set()               # registered blocks fully written
        self.evictable: "OrderedDict[int, None]" = OrderedDict()  # LRU
        self.evictions = 0
        # per-prefix-hash counters for tuning the evictable LRU:
        # hash -> [hits, misses, evictions].  A hit/miss is attributed by
        # ``lookup`` (``peek`` is a budget probe and never counts); an
        # eviction is attributed to the evicted block's hash.
        self.prefix_stats: Dict[int, List[int]] = {}

    def _stat(self, h: int) -> List[int]:
        return self.prefix_stats.setdefault(h, [0, 0, 0])

    @property
    def available(self) -> int:
        """Blocks an admission may claim: free plus evictable-cached."""
        return len(self.free_stack) + len(self.evictable)

    @property
    def in_use(self) -> int:
        """Blocks owned by live requests (excludes free and cached-idle)."""
        return max(self.num_blocks - 1, 0) - self.available

    def shard_accounting(self, n_devices: int) -> List[Dict[str, int]]:
        """Per-device block accounting for a tensor-parallel sharded pool.

        The pool shards the KV *feature* dims (heads x head_dim), never the
        block axis: every device holds its head-shard of every block, and the
        host-managed block tables index each device's pool identically.  So
        device ``d``'s pool mirrors the logical partition exactly — a block
        live for request ``r`` is live for ``r`` on every device (no
        cross-device aliasing), and ``free + in_use + evictable`` tiles the
        allocatable blocks ``1..num_blocks-1`` on each shard.
        """
        assert n_devices >= 1, n_devices
        allocatable = max(self.num_blocks - 1, 0)
        free = len(self.free_stack)
        evictable = len(self.evictable)
        in_use = allocatable - free - evictable
        view = {"free": free, "in_use": in_use, "evictable": evictable,
                "allocatable": allocatable}
        return [dict(view) for _ in range(n_devices)]

    def allocate(self, n: int) -> List[int]:
        """Pop ``n`` blocks, evicting LRU cached blocks under pressure."""
        assert n <= self.available, (
            f"allocate({n}) with only {self.available} blocks available")
        out = []
        for _ in range(n):
            if self.free_stack:
                out.append(self.free_stack.pop())
            else:
                out.append(self._evict_lru())
        return out

    def _evict_lru(self) -> int:
        blk, _ = self.evictable.popitem(last=False)
        # an evictable block by construction has no live readers
        assert self.refs.get(blk, 0) == 0, f"evicting live block {blk}"
        h = self.hash_of.get(blk)
        if h is not None:
            self._stat(h)[2] += 1
        self._unregister(blk)
        self.evictions += 1
        return blk

    def _unregister(self, blk: int) -> None:
        h = self.hash_of.pop(blk, None)
        if h is not None:
            del self.block_of[h]
        self.refs.pop(blk, None)
        self.ready.discard(blk)

    def register(self, h: int, blk: int) -> bool:
        """Claim hash ``h`` for ``blk`` (owner holds one ref; not yet
        ready).  False if the hash is already registered — the caller's
        block then simply stays private."""
        if h in self.block_of:
            return False
        self.block_of[h] = blk
        self.hash_of[blk] = h
        self.refs[blk] = 1
        return True

    def mark_ready(self, blk: int) -> None:
        """Make a registered block's content visible to ``lookup``."""
        if blk in self.hash_of:
            self.ready.add(blk)

    def peek(self, hashes: List[int]) -> int:
        """Conservative hit estimate for admission budgeting: leading
        blocks that are registered, ready, and currently referenced.  An
        evictable block is *not* counted — an interleaved allocation could
        evict it before the admission commits — so ``peek`` never
        overstates what ``lookup`` will find."""
        n = 0
        for h in hashes:
            blk = self.block_of.get(h)
            if blk is None or blk not in self.ready or self.refs.get(blk, 0) <= 0:
                break
            n += 1
        return n

    def lookup(self, hashes: List[int]) -> List[int]:
        """Longest ready cached prefix of ``hashes``; increfs each matched
        block (resurrecting evictable ones) and returns their ids in
        prefix order."""
        out: List[int] = []
        for h in hashes:
            blk = self.block_of.get(h)
            if blk is None or blk not in self.ready:
                self._stat(h)[1] += 1  # first break ends the usable prefix
                break
            if self.refs[blk] == 0:
                del self.evictable[blk]  # resurrected before eviction
            self.refs[blk] += 1
            self._stat(h)[0] += 1
            out.append(blk)
        return out

    def free(self, blocks: List[int]) -> None:
        """Return a request's blocks.  Shared blocks decref — the last
        reader parks the block (content and registration intact) on the
        evictable LRU; a registered-but-never-ready block (its request
        finished mid-prefill) is useless to future readers and is
        unregistered outright.  Private blocks go back on the free stack.

        Parking walks the table in *reverse* so a chain's tail blocks are
        LRU-oldest and evict first: lookups match a leading run of the
        chained hashes, so evicting a chain head would strand the rest of
        the cached chain as unmatchable dead weight, while evicting tails
        degrades a cached prefix gracefully from the right."""
        for blk in reversed(blocks):
            if blk in self.hash_of:
                self.refs[blk] -= 1
                assert self.refs[blk] >= 0, f"double free of block {blk}"
                if self.refs[blk] == 0:
                    if blk in self.ready:
                        self.evictable[blk] = None
                    else:
                        self._unregister(blk)
                        self.free_stack.append(blk)
            else:
                self.free_stack.append(blk)


def init_attn_cache(
    batch: int,
    max_len: int,
    n_kv: int,
    head_dim: int,
    dtype,
    window: int = 0,
) -> Dict:
    length = min(window, max_len) if window > 0 else max_len
    return {
        "k": jnp.zeros((batch, length, n_kv, head_dim), dtype),
        "v": jnp.zeros((batch, length, n_kv, head_dim), dtype),
        "pos": jnp.full((batch, length), -1, jnp.int32),  # per-row positions
        "ring": jnp.asarray(1 if (window > 0 and window < max_len) else 0, jnp.int32),
    }


def fill_attn_cache(cache: Dict, k: jax.Array, v: jax.Array, positions: jax.Array) -> Dict:
    """Write a full prefill's K/V (B, S, H, D) into the cache.

    For ring caches only the last ``L`` timesteps are kept.  ``positions`` is
    (B, S) but all rows are identical in the batched-serving setting; row 0 is
    used for the slot bookkeeping.
    """
    B, S = k.shape[:2]
    L = cache["k"].shape[1]
    pos_row = positions[0].astype(jnp.int32)
    if S >= L:
        k_tail, v_tail, p_tail = k[:, S - L:], v[:, S - L:], pos_row[S - L:]
    else:
        pad = L - S
        k_tail = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_tail = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        p_tail = jnp.pad(pos_row, (0, pad), constant_values=-1)
    slots = jnp.where(p_tail >= 0, p_tail % L, jnp.arange(L) % L)
    k_new = cache["k"].at[:, slots].set(k_tail)
    v_new = cache["v"].at[:, slots].set(v_tail)
    pos_new = cache["pos"].at[:, slots].set(p_tail[None, :])
    return {"k": k_new, "v": v_new, "pos": pos_new, "ring": cache["ring"]}


def update_attn_cache(cache: Dict, k_new: jax.Array, v_new: jax.Array,
                      positions: jax.Array,
                      update_mask: jax.Array = None) -> Dict:
    """Write one decoded token's K/V (B, 1, H, D) at per-row ``positions`` (B,).

    ``update_mask`` (B,) bool, when given, turns masked-off rows into no-op
    writes (the current cache content is written back).  The fused serving
    step uses it so idle and mid-prefill slots never clobber ring entries
    that a chunked prefill is concurrently filling.
    """
    B, L = cache["pos"].shape
    positions = jnp.broadcast_to(jnp.asarray(positions, jnp.int32), (B,))
    slot = positions % L
    rows = jnp.arange(B)
    k_w, v_w, p_w = k_new[:, 0], v_new[:, 0], positions
    if update_mask is not None:
        m = update_mask.reshape(B, 1, 1)
        k_w = jnp.where(m, k_w, cache["k"][rows, slot])
        v_w = jnp.where(m, v_w, cache["v"][rows, slot])
        p_w = jnp.where(update_mask, p_w, cache["pos"][rows, slot])
    k = cache["k"].at[rows, slot].set(k_w)
    v = cache["v"].at[rows, slot].set(v_w)
    pos = cache["pos"].at[rows, slot].set(p_w)
    return {"k": k, "v": v, "pos": pos, "ring": cache["ring"]}


def append_attn_cache(cache: Dict, k: jax.Array, v: jax.Array,
                      positions: jax.Array,
                      valid: jax.Array = None) -> Dict:
    """Write a prompt chunk's K/V (B, C, H, D) at absolute ``positions``
    (B, C) into a contiguous or ring cache, preserving existing entries.

    Unlike ``fill_attn_cache`` (whole-prompt, fresh cache) this scatters
    only the chunk's own C columns, so chunk N lands next to chunks
    0..N-1.  A chunk longer than a ring keeps its tail (earlier chunk
    positions would be evicted immediately anyway).

    ``valid`` (B, C) bool, when given, turns masked-off entries into no-op
    writes (the current cache content is written back) — the unified
    mixed-batch step packs ragged per-slot chunks into one static-width
    batch, and pad columns must not clobber live entries."""
    B, C = k.shape[:2]
    L = cache["k"].shape[1]
    if C > L and valid is None:
        # ring shorter than the chunk: only the tail survives
        k, v, positions = k[:, C - L:], v[:, C - L:], positions[:, C - L:]
        C = L
    elif C > L:
        # ragged rows: keep each row's last <= L *valid* entries (a static
        # tail slice would drop live entries of rows shorter than C).
        # Chunk positions are consecutive within a row, so recomputing
        # them arithmetically keeps the gathered window's slots distinct
        # even where the gather index saturates at C - 1 (those entries
        # are masked invalid and write back the old cache values).
        n = valid.sum(axis=1, dtype=jnp.int32)            # (B,)
        base = jnp.maximum(n - L, 0)                      # window start
        idx = base[:, None] + jnp.arange(L, dtype=jnp.int32)[None]
        gat = jnp.minimum(idx, C - 1)[..., None, None]
        k = jnp.take_along_axis(k, gat, axis=1)
        v = jnp.take_along_axis(v, gat, axis=1)
        positions = positions[:, :1] + idx
        valid = idx < n[:, None]
        C = L
    rows = jnp.arange(B)[:, None]
    slots = positions % L
    k = k.astype(cache["k"].dtype)
    v = v.astype(cache["v"].dtype)
    if valid is not None:
        # within a row the C slots are distinct (consecutive positions mod
        # L with C <= L), so write-back of the old value is a sound no-op
        m = valid[..., None, None]
        k = jnp.where(m, k, cache["k"][rows, slots])
        v = jnp.where(m, v, cache["v"][rows, slots])
        positions = jnp.where(valid, positions, cache["pos"][rows, slots])
    return {
        "k": cache["k"].at[rows, slots].set(k),
        "v": cache["v"].at[rows, slots].set(v),
        "pos": cache["pos"].at[rows, slots].set(positions),
        "ring": cache["ring"],
    }


# -- paged (block-pool) attention cache --------------------------------------

def init_paged_attn_cache(
    num_blocks: int, block_size: int, n_kv: int, head_dim: int, dtype
) -> Dict:
    return {
        "kp": jnp.zeros((num_blocks, block_size, n_kv, head_dim), dtype),
        "vp": jnp.zeros((num_blocks, block_size, n_kv, head_dim), dtype),
    }


def fill_paged_cache(
    cache: Dict, k: jax.Array, v: jax.Array, positions: jax.Array,
    block_tables: jax.Array,
) -> Dict:
    """Scatter a full prefill's K/V (B, S, H, D) into pool blocks.

    The prompt occupies absolute positions 0..S-1, so row ``b`` fills table
    entries ``0..ceil(S/bs)-1`` of ``block_tables[b]`` in order.  S is
    padded up to a whole number of blocks; the pad tail lands at positions
    >= S inside the last block and is hidden by causal masking.
    """
    del positions  # prompt positions are 0..S-1 by construction
    B, S = k.shape[:2]
    bs = cache["kp"].shape[1]
    nb = -(-S // bs)
    pad = nb * bs - S
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    idx = block_tables[:, :nb].reshape(-1)
    kb = k.reshape(B * nb, bs, *k.shape[2:]).astype(cache["kp"].dtype)
    vb = v.reshape(B * nb, bs, *v.shape[2:]).astype(cache["vp"].dtype)
    return {"kp": cache["kp"].at[idx].set(kb), "vp": cache["vp"].at[idx].set(vb)}


def update_paged_cache(
    cache: Dict, k_new: jax.Array, v_new: jax.Array, positions: jax.Array,
    block_tables: jax.Array, update_mask: jax.Array = None,
) -> Dict:
    """Write one decoded token's K/V (B, 1, H, D) at per-row ``positions``.

    Active slots always have the covering block allocated (admission
    reserves blocks for prompt + budget); idle slots' tables point at the
    garbage block, so their static-shape writes land in trash.
    ``update_mask`` (B,) bool additionally routes masked-off rows to the
    garbage block regardless of their table row — the engine arms a slot's
    real table row when it becomes decode-eligible, and only the chunked
    prefill may write its blocks before that.
    """
    B = block_tables.shape[0]
    positions = jnp.broadcast_to(jnp.asarray(positions, jnp.int32), (B,))
    bs = cache["kp"].shape[1]
    rows = jnp.arange(B)
    blk = block_tables[rows, positions // bs]
    if update_mask is not None:
        blk = jnp.where(update_mask, blk, GARBAGE_BLOCK)
    off = positions % bs
    kp = cache["kp"].at[blk, off].set(k_new[:, 0].astype(cache["kp"].dtype))
    vp = cache["vp"].at[blk, off].set(v_new[:, 0].astype(cache["vp"].dtype))
    return {"kp": kp, "vp": vp}


def append_paged_cache(
    cache: Dict, k: jax.Array, v: jax.Array, positions: jax.Array,
    block_tables: jax.Array, valid: jax.Array = None,
) -> Dict:
    """Scatter a prompt chunk's K/V (B, C, H, D) at absolute ``positions``
    (B, C) into pool blocks through the block tables.

    Unlike ``fill_paged_cache`` (whole prompt, block-aligned from position
    0) the chunk may start and end anywhere inside a block, so each token
    is routed individually: position ``p`` lands at
    ``pool[table[b, p // bs], p % bs]``.

    ``valid`` (B, C) bool, when given, routes masked-off entries to the
    garbage block: the unified mixed-batch step packs ragged per-slot
    chunks into one static-width batch, and a pad column's position may
    exceed the row's allocation (or the whole row may be idle)."""
    bs = cache["kp"].shape[1]
    idx = positions // bs
    if valid is not None:
        # pad positions can run past the table width; clamp before gather
        idx = jnp.clip(idx, 0, block_tables.shape[1] - 1)
    blk = jnp.take_along_axis(block_tables, idx, axis=1)  # (B, C)
    if valid is not None:
        blk = jnp.where(valid, blk, GARBAGE_BLOCK)
    off = positions % bs
    kp = cache["kp"].at[blk, off].set(k.astype(cache["kp"].dtype))
    vp = cache["vp"].at[blk, off].set(v.astype(cache["vp"].dtype))
    return {"kp": kp, "vp": vp}


def gather_paged_kv(cache: Dict, block_tables: jax.Array):
    """Materialize each row's pool blocks as dense K/V plus key positions.

    Returns ``(k, v, k_positions)`` of shapes (B, M*bs, H, D) x2 and
    (B, M*bs) where M is the block-table width.  Gathered index ``j`` *is*
    absolute position ``j``; table entries beyond the row's allocation
    point at the garbage block, whose logical positions exceed every
    prompt position and are hidden by causal masking.  Used by the chunked
    prefill (chunk N attends to cached chunks 0..N-1 plus itself);
    decode-side reads go through the scalar-prefetch Pallas kernel
    instead, which never materializes this gather."""
    kp, vp = cache["kp"], cache["vp"]
    B, M = block_tables.shape
    bs = kp.shape[1]
    k = kp[block_tables].reshape(B, M * bs, *kp.shape[2:])
    v = vp[block_tables].reshape(B, M * bs, *vp.shape[2:])
    pos = jnp.broadcast_to(jnp.arange(M * bs, dtype=jnp.int32)[None], (B, M * bs))
    return k, v, pos


# -- recurrent states --------------------------------------------------------

def init_rglru_state(batch: int, width: int, conv_width: int, dtype) -> Dict:
    return {
        "h": jnp.zeros((batch, width), jnp.float32),
        "conv": jnp.zeros((batch, conv_width - 1, width), dtype),
    }


def init_mlstm_state(
    batch: int, heads: int, dk: int, dv: int, conv_width: int = 0, dtype=jnp.float32
) -> Dict:
    st = {
        "C": jnp.zeros((batch, heads, dk, dv), jnp.float32),
        "n": jnp.zeros((batch, heads, dk), jnp.float32),
        "m": jnp.full((batch, heads), -1e30, jnp.float32),
    }
    if conv_width > 0:
        st["conv"] = jnp.zeros((batch, conv_width - 1, heads * dv), dtype)
    return st


def init_slstm_state(batch: int, heads: int, dh: int, conv_width: int, dtype) -> Dict:
    return {
        "c": jnp.zeros((batch, heads, dh), jnp.float32),
        "n": jnp.zeros((batch, heads, dh), jnp.float32),
        "m": jnp.full((batch, heads, dh), -1e30, jnp.float32),
        "h": jnp.zeros((batch, heads, dh), jnp.float32),
        "conv": jnp.zeros((batch, conv_width - 1, heads * dh), dtype),
    }


# -- per-block cache constructors -------------------------------------------

def init_block_cache(
    cfg: ModelConfig, kind: str, batch: int, max_len: int, dtype,
    *, layout: str = "contiguous", block_size: int = 16,
    num_blocks: int = 0,
) -> Dict:
    hd = cfg.resolved_head_dim
    if kind == "ffn":
        return {}
    if kind == "attn":
        if layout == "paged":
            n = num_blocks or default_num_blocks(batch, max_len, block_size)
            return init_paged_attn_cache(n, block_size, cfg.num_kv_heads, hd, dtype)
        return init_attn_cache(batch, max_len, cfg.num_kv_heads, hd, dtype)
    if kind == "local_attn":
        return init_attn_cache(
            batch, max_len, cfg.num_kv_heads, hd, dtype, window=cfg.sliding_window
        )
    if kind == "rglru":
        return init_rglru_state(
            batch, cfg.resolved_lru_width, cfg.rglru_conv_width, dtype
        )
    if kind == "mlstm":
        w = int(cfg.d_model * cfg.mlstm_proj_factor)
        h = cfg.resolved_rec_heads
        return init_mlstm_state(batch, h, w // h, w // h, cfg.rglru_conv_width, dtype)
    if kind == "slstm":
        h = cfg.resolved_rec_heads
        return init_slstm_state(batch, h, cfg.d_model // h, cfg.rglru_conv_width, dtype)
    raise ValueError(f"no cache for block kind {kind!r}")


def cache_bytes(cache) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))
