"""Recurrent blocks: Griffin RG-LRU (RecurrentGemma) and xLSTM (mLSTM/sLSTM).

Each block exposes two entry points:
  * ``apply_*_seq``  — full-sequence (train / prefill): chunked-parallel where
    the math allows (RG-LRU associative scan, mLSTM chunkwise), sequential
    ``lax.scan`` where it does not (sLSTM's nonlinear recurrence);
    returns (y, final_state).
  * ``apply_*_step`` — single-token decode against a carried state.

States are the cache pytrees from ``models/cache.py``; all recurrences are
carried in fp32 with log-space max-stabilizers (the xLSTM formulation).
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import dispatch
from repro.models import flags
from repro.models.config import ModelConfig
from repro.models.layers import Maker, act_fn, rms_norm, shard

_LOG_EPS = -1e30


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------

def _causal_conv(x: jax.Array, w: jax.Array, history: jax.Array = None,
                 lengths: jax.Array = None):
    """Depthwise causal conv, width K, via shifted adds.

    x: (B, S, W); w: (K, W).  ``history``: (B, K-1, W) previous inputs (decode
    / chunk boundary).  Returns (y, new_history).

    ``lengths`` (B,), when given, marks each row's valid prefix of ``x``:
    the returned history is then gathered per row from the last K-1 *valid*
    inputs (``xp[b, len_b : len_b + K-1]``) instead of the tail, so ragged
    rows in a packed chunk batch carry the right conv state forward.  A
    zero-length row keeps its old history.  Conv *outputs* at pad positions
    are garbage; callers mask or discard them.
    """
    K = w.shape[0]
    B, S, W = x.shape
    if history is None:
        history = jnp.zeros((B, K - 1, W), x.dtype)
    xp = jnp.concatenate([history, x], axis=1)  # (B, S+K-1, W)
    y = jnp.zeros_like(x)
    for i in range(K):
        y = y + xp[:, i : i + S] * w[K - 1 - i]
    if K <= 1:
        new_hist = history
    elif lengths is None:
        new_hist = xp[:, S:, :]
    else:
        idx = lengths[:, None] + jnp.arange(K - 1, dtype=jnp.int32)[None]
        new_hist = jnp.take_along_axis(xp, idx[..., None], axis=1)
    return y, new_hist


def _block_diag_linear(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: (..., H*Dh) @ block-diagonal w: (H, Dh, Do) -> (..., H*Do)."""
    H, Dh, Do = w.shape
    xh = x.reshape(*x.shape[:-1], H, Dh)
    y = jnp.einsum("...hd,hdo->...ho", xh, w)
    return y.reshape(*x.shape[:-1], H * Do)


# ---------------------------------------------------------------------------
# RG-LRU (Griffin / RecurrentGemma recurrent block)
# ---------------------------------------------------------------------------

def make_rglru_block(mk: Maker, cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    W = cfg.resolved_lru_width
    H = cfg.resolved_rec_heads
    Dh = W // H
    # Λ init so that a = exp(-8*softplus(λ)) lands in [0.9, 0.999] (Griffin).
    import numpy as np

    u = np.random.RandomState(0).uniform(0.9 ** 2, 0.999 ** 2, size=(W,))
    lam = np.log(np.expm1(-np.log(u) / (2 * 8.0)))  # inverse softplus
    return {
        "in_x": mk.normal((d, W), ("embed", "lru")),       # recurrent branch
        "in_g": mk.normal((d, W), ("embed", "lru")),       # gate branch
        "conv_w": mk.normal((cfg.rglru_conv_width, W), ("conv", "lru"), scale=0.1),
        "gate_a": mk.normal((H, Dh, Dh), (None, "lru", None), scale=1.0 / math.sqrt(Dh)),
        "gate_x": mk.normal((H, Dh, Dh), (None, "lru", None), scale=1.0 / math.sqrt(Dh)),
        "lambda": mk.const(jnp.asarray(lam, jnp.float32), ("lru",)),
        "out": mk.normal((W, d), ("lru", "embed"), scale=1.0 / math.sqrt(W)),
    }


def _rglru_gates(p, xc: jax.Array):
    """Per-timestep decay a (fp32) and gated input, from conv'd branch xc."""
    r = jax.nn.sigmoid(_block_diag_linear(xc, p["gate_a"]).astype(jnp.float32))
    i = jax.nn.sigmoid(_block_diag_linear(xc, p["gate_x"]).astype(jnp.float32))
    log_a = -8.0 * jax.nn.softplus(p["lambda"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) input normalization (Griffin eq. 4)
    beta = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-12, 1.0))
    b = beta * (i * xc.astype(jnp.float32))
    return a, b


def apply_rglru_seq(p, x, cfg: ModelConfig, state=None,
                    valid: jax.Array = None) -> Tuple[jax.Array, Dict]:
    from repro.models import cache as cache_lib

    B, S, d = x.shape
    W = cfg.resolved_lru_width
    if state is None:
        state = cache_lib.init_rglru_state(B, W, cfg.rglru_conv_width, x.dtype)
    g = act_fn("gelu")(jnp.einsum("bsd,dw->bsw", x, p["in_g"]))
    xr = jnp.einsum("bsd,dw->bsw", x, p["in_x"])
    xr = shard(xr, "batch", None, "act_ffn")
    lengths = valid.sum(axis=1, dtype=jnp.int32) if valid is not None else None
    xc, conv_hist = _causal_conv(xr, p["conv_w"], state["conv"], lengths)
    a, b = _rglru_gates(p, xc)
    if valid is not None:
        # pad steps are identity: h_t = 1*h_{t-1} + 0, so h[:, -1] is the
        # state after each row's last *valid* input
        m = valid[..., None]
        a = jnp.where(m, a, 1.0)
        b = jnp.where(m, b, 0.0)
    h = dispatch.linear_recurrence(a, b, state["h"])  # (B, S, W) fp32
    y = (h.astype(x.dtype) * g)
    y = jnp.einsum("bsw,wd->bsd", y, p["out"])
    new_state = {"h": h[:, -1], "conv": conv_hist}
    return shard(y, "batch", None, "act_embed"), new_state


def apply_rglru_step(p, x, cfg: ModelConfig, state) -> Tuple[jax.Array, Dict]:
    """x: (B, 1, d) single decode step."""
    y, new_state = apply_rglru_seq(p, x, cfg, state)
    return y, new_state


# ---------------------------------------------------------------------------
# mLSTM (xLSTM matrix-memory cell, chunkwise-parallel)
# ---------------------------------------------------------------------------

def make_mlstm_block(mk: Maker, cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    W = int(d * cfg.mlstm_proj_factor)
    H = cfg.resolved_rec_heads
    return {
        "up_u": mk.normal((d, W), ("embed", "ffn")),
        "up_z": mk.normal((d, W), ("embed", "ffn")),
        "conv_w": mk.normal((cfg.rglru_conv_width, W), ("conv", "ffn"), scale=0.1),
        "wq": mk.normal((H, W // H, W // H), (None, "ffn", None),
                        scale=1.0 / math.sqrt(W // H)),
        "wk": mk.normal((H, W // H, W // H), (None, "ffn", None),
                        scale=1.0 / math.sqrt(W // H)),
        "wv": mk.normal((H, W // H, W // H), (None, "ffn", None),
                        scale=1.0 / math.sqrt(W // H)),
        "w_i": mk.normal((W, H), ("ffn", None), scale=0.01),
        "b_i": mk.zeros((H,), (None,)),
        "w_f": mk.normal((W, H), ("ffn", None), scale=0.01),
        "b_f": mk.const(jnp.linspace(3.0, 6.0, H), (None,)),  # long-memory init
        "norm_scale": mk.zeros((W,), ("ffn",)),
        "down": mk.normal((W, d), ("ffn", "embed"), scale=1.0 / math.sqrt(W)),
    }


def _mlstm_chunk_scan(q, k, v, log_i, log_f, state, chunk: int):
    """Chunkwise-parallel stabilized mLSTM.

    q,k,v: (B, S, H, D) fp32;  log_i/log_f: (B, S, H) fp32.
    state: dict(C (B,H,D,D), n (B,H,D), m (B,H)).
    Returns h (B, S, H, D) fp32 and final state.
    """
    B, S, H, D = q.shape
    L = min(chunk, S)
    S_orig = S
    if S % L:
        # pad to a chunk multiple with identity steps: log_f=0 (keep state),
        # log_i=-2e30 (< the -1e30 initial stabilizer, so pads contribute 0)
        pad = L - S % L
        zpad = lambda t: jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
        q, k, v = zpad(q), zpad(k), zpad(v)
        log_f = zpad(log_f)
        log_i = jnp.pad(log_i, ((0, 0), (0, pad), (0, 0)), constant_values=-2e30)
        S = S + pad
    N = S // L

    def per_chunk(carry, xs):
        C, n, m = carry                       # (B,H,D,D), (B,H,D), (B,H)
        qc, kc, vc, li, lf = xs               # (B,L,H,D) / (B,L,H)
        qc = jnp.swapaxes(qc, 1, 2)           # (B,H,L,D)
        kc = jnp.swapaxes(kc, 1, 2)
        vc = jnp.swapaxes(vc, 1, 2)
        li = jnp.swapaxes(li, 1, 2)           # (B,H,L)
        lf = jnp.swapaxes(lf, 1, 2)
        b = jnp.cumsum(lf, axis=-1)           # inclusive log-decay to t
        a = li - b                            # a_s = log_i_s - b_s
        cummax_a = jax.lax.cummax(a, axis=a.ndim - 1)
        mm = jnp.maximum(m[..., None], cummax_a)          # (B,H,L)
        m_t = b + mm
        # intra-chunk scores
        scale = 1.0 / math.sqrt(D)
        s_qk = jnp.einsum("bhld,bhmd->bhlm", qc, kc) * scale
        decay = a[:, :, None, :] - mm[:, :, :, None]      # (B,H,L(t),L(s))
        causal = jnp.tril(jnp.ones((L, L), bool))
        w_intra = jnp.where(causal, jnp.exp(decay), 0.0)
        s_w = s_qk * w_intra
        h_intra = jnp.einsum("bhlm,bhmd->bhld", s_w, vc)
        n_intra = jnp.einsum("bhlm,bhmd->bhld", w_intra, kc)  # normalizer state at t
        # inter-chunk (carry) contribution
        w_inter = jnp.exp(m[..., None] - mm)              # (B,H,L)
        h_inter = jnp.einsum("bhld,bhde->bhle", qc * scale, C) * w_inter[..., None]
        num = h_intra + h_inter
        # denominator: |q·n_t| with n_t the stabilized normalizer state at t
        n_at_t = n_intra + n[:, :, None, :] * w_inter[..., None]
        denom = jnp.abs(jnp.einsum("bhld,bhld->bhl", qc * scale, n_at_t))
        denom = jnp.maximum(denom, jnp.exp(-m_t))
        h = num / denom[..., None]
        # end-of-chunk state
        g = b[..., -1]                                    # (B,H)
        m_next = m_t[..., -1]
        w_c = jnp.exp(g[..., None] + a - m_next[..., None])          # (B,H,L)
        C_next = (
            jnp.exp(g + m - m_next)[..., None, None] * C
            + jnp.einsum("bhl,bhld,bhle->bhde", w_c, kc, vc)
        )
        n_next = (
            jnp.exp(g + m - m_next)[..., None] * n
            + jnp.einsum("bhl,bhld->bhd", w_c, kc)
        )
        return (C_next, n_next, m_next), jnp.swapaxes(h, 1, 2)  # (B,L,H,D)

    xs = tuple(
        t.reshape(B, N, L, *t.shape[2:]).swapaxes(0, 1)
        for t in (q, k, v, log_i, log_f)
    )
    (C, n, m), hs = jax.lax.scan(per_chunk, (state["C"], state["n"], state["m"]), xs,
                                 unroll=N if flags.unroll_scans() else 1)
    h = hs.swapaxes(0, 1).reshape(B, S, H, D)[:, :S_orig]
    return h, {"C": C, "n": n, "m": m}


def apply_mlstm_seq(p, x, cfg: ModelConfig, state=None,
                    valid: jax.Array = None) -> Tuple[jax.Array, Dict]:
    from repro.models import cache as cache_lib

    B, S, d = x.shape
    W = int(d * cfg.mlstm_proj_factor)
    H = cfg.resolved_rec_heads
    D = W // H
    if state is None:
        state = cache_lib.init_mlstm_state(B, H, D, D)
        conv_hist = None
    else:
        conv_hist = state.get("conv")
    u = jnp.einsum("bsd,dw->bsw", x, p["up_u"])
    z = jnp.einsum("bsd,dw->bsw", x, p["up_z"])
    u = shard(u, "batch", None, "act_ffn")
    lengths = valid.sum(axis=1, dtype=jnp.int32) if valid is not None else None
    uc, new_hist = _causal_conv(u, p["conv_w"], conv_hist, lengths)
    uc = act_fn("silu")(uc)
    q = _block_diag_linear(uc, p["wq"]).reshape(B, S, H, D).astype(jnp.float32)
    k = _block_diag_linear(uc, p["wk"]).reshape(B, S, H, D).astype(jnp.float32)
    v = _block_diag_linear(u, p["wv"]).reshape(B, S, H, D).astype(jnp.float32)
    log_i = (jnp.einsum("bsw,wh->bsh", uc, p["w_i"]) + p["b_i"]).astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(
        (jnp.einsum("bsw,wh->bsh", uc, p["w_f"]) + p["b_f"]).astype(jnp.float32)
    )
    if valid is not None:
        # identity steps at pads, same trick as the chunk-scan's own
        # padding: log_f=0 keeps the carry, log_i=-2e30 contributes nothing
        m = valid[..., None]
        log_f = jnp.where(m, log_f, 0.0)
        log_i = jnp.where(m, log_i, -2e30)
    cell_state = {"C": state["C"], "n": state["n"], "m": state["m"]}
    h, new_cell = _mlstm_chunk_scan(q, k, v, log_i, log_f, cell_state, cfg.recurrent_chunk)
    h = h.reshape(B, S, W).astype(x.dtype)
    h = rms_norm(h, p["norm_scale"], 1e-6)
    y = jnp.einsum("bsw,wd->bsd", h * act_fn("silu")(z), p["down"])
    new_state = dict(new_cell)
    new_state["conv"] = new_hist
    return shard(y, "batch", None, "act_embed"), new_state


def apply_mlstm_step(p, x, cfg: ModelConfig, state) -> Tuple[jax.Array, Dict]:
    """Single-token decode: O(1) state update (B,1,d)."""
    B, _, d = x.shape
    W = int(d * cfg.mlstm_proj_factor)
    H = cfg.resolved_rec_heads
    D = W // H
    u = jnp.einsum("bsd,dw->bsw", x, p["up_u"])
    z = jnp.einsum("bsd,dw->bsw", x, p["up_z"])
    uc, new_hist = _causal_conv(u, p["conv_w"], state["conv"])
    uc = act_fn("silu")(uc)
    q = _block_diag_linear(uc, p["wq"]).reshape(B, H, D).astype(jnp.float32)
    k = _block_diag_linear(uc, p["wk"]).reshape(B, H, D).astype(jnp.float32)
    v = _block_diag_linear(u, p["wv"]).reshape(B, H, D).astype(jnp.float32)
    log_i = (jnp.einsum("bw,wh->bh", uc[:, 0], p["w_i"]) + p["b_i"]).astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(
        (jnp.einsum("bw,wh->bh", uc[:, 0], p["w_f"]) + p["b_f"]).astype(jnp.float32)
    )
    scale = 1.0 / math.sqrt(D)
    m_new = jnp.maximum(log_f + state["m"], log_i)
    w_old = jnp.exp(log_f + state["m"] - m_new)
    w_in = jnp.exp(log_i - m_new)
    C = w_old[..., None, None] * state["C"] + w_in[..., None, None] * (
        k[..., :, None] * v[..., None, :]
    )
    n = w_old[..., None] * state["n"] + w_in[..., None] * k
    num = jnp.einsum("bhd,bhde->bhe", q * scale, C)
    denom = jnp.maximum(
        jnp.abs(jnp.einsum("bhd,bhd->bh", q * scale, n)), jnp.exp(-m_new)
    )
    h = (num / denom[..., None]).reshape(B, 1, W).astype(x.dtype)
    h = rms_norm(h, p["norm_scale"], 1e-6)
    y = jnp.einsum("bsw,wd->bsd", h * act_fn("silu")(z), p["down"])
    return y, {"C": C, "n": n, "m": m_new, "conv": new_hist}


# ---------------------------------------------------------------------------
# sLSTM (xLSTM scalar-memory cell, sequential scan)
# ---------------------------------------------------------------------------

def make_slstm_block(mk: Maker, cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    H = cfg.resolved_rec_heads
    Dh = d // H
    ff = int(d * cfg.slstm_proj_factor)
    gates = {}
    for name in ("z", "i", "f", "o"):
        gates[f"w_{name}"] = mk.normal((d, d), ("embed", None))
        gates[f"r_{name}"] = mk.normal((H, Dh, Dh), (None, None, None),
                                       scale=1.0 / math.sqrt(Dh))
        gates[f"b_{name}"] = (
            mk.const(jnp.linspace(3.0, 6.0, d).reshape(H, Dh), (None, None))
            if name == "f" else mk.zeros((H, Dh), (None, None))
        )
    return {
        **gates,
        "conv_w": mk.normal((cfg.rglru_conv_width, d), ("conv", "embed"), scale=0.1),
        "norm_scale": mk.zeros((d,), ("embed",)),
        "ff_up": mk.normal((d, ff), ("embed", "ffn")),
        "ff_down": mk.normal((ff, d), ("ffn", "embed"), scale=1.0 / math.sqrt(ff)),
    }


def _slstm_cell(p, H, Dh, carry, xs):
    c, n, m, h = carry                        # each (B, H, Dh) fp32
    zx, ix, fx, ox = xs                       # pre-activations from x: (B, H, Dh)
    rec = lambda name: jnp.einsum(
        "bhd,hde->bhe", h.astype(zx.dtype), p[f"r_{name}"]
    ).astype(jnp.float32)
    z = jnp.tanh(zx.astype(jnp.float32) + rec("z"))
    log_i = ix.astype(jnp.float32) + rec("i")
    log_f = jax.nn.log_sigmoid(fx.astype(jnp.float32) + rec("f"))
    o = jax.nn.sigmoid(ox.astype(jnp.float32) + rec("o"))
    m_new = jnp.maximum(log_f + m, log_i)
    c_new = jnp.exp(log_f + m - m_new) * c + jnp.exp(log_i - m_new) * z
    n_new = jnp.maximum(jnp.exp(log_f + m - m_new) * n + jnp.exp(log_i - m_new), 1e-6)
    h_new = o * (c_new / n_new)
    return (c_new, n_new, m_new, h_new), h_new


def apply_slstm_seq(p, x, cfg: ModelConfig, state=None,
                    valid: jax.Array = None) -> Tuple[jax.Array, Dict]:
    from repro.models import cache as cache_lib

    B, S, d = x.shape
    H = cfg.resolved_rec_heads
    Dh = d // H
    if state is None:
        state = cache_lib.init_slstm_state(B, H, Dh, cfg.rglru_conv_width, x.dtype)
    lengths = valid.sum(axis=1, dtype=jnp.int32) if valid is not None else None
    xc, new_hist = _causal_conv(x, p["conv_w"], state["conv"], lengths)
    xc = act_fn("silu")(xc)
    pre = {}
    for name, src in (("z", x), ("i", xc), ("f", xc), ("o", x)):
        pre[name] = (
            jnp.einsum("bsd,de->bse", src, p[f"w_{name}"]).reshape(B, S, H, Dh)
            + p[f"b_{name}"]
        )
    xs = tuple(jnp.swapaxes(pre[name], 0, 1) for name in ("z", "i", "f", "o"))
    carry = (state["c"], state["n"], state["m"], state["h"])

    def cell(carry_t, xs_t):
        new_carry, h_new = _slstm_cell(p, H, Dh, carry_t, xs_t[:4])
        if valid is not None:
            v_t = xs_t[4][:, None, None]  # (B, 1, 1)
            new_carry = tuple(
                jnp.where(v_t, nw, od) for nw, od in zip(new_carry, carry_t)
            )
            h_new = new_carry[3]
        return new_carry, h_new

    if valid is not None:
        xs = xs + (jnp.swapaxes(valid, 0, 1),)
    (c, n, m, h_fin), hs = jax.lax.scan(cell, carry, xs)
    h = jnp.swapaxes(hs, 0, 1).reshape(B, S, d).astype(x.dtype)
    h = rms_norm(h, p["norm_scale"], 1e-6)
    y = jnp.einsum("bsf,fd->bsd", act_fn("gelu")(
        jnp.einsum("bsd,df->bsf", h, p["ff_up"])), p["ff_down"])
    new_state = {"c": c, "n": n, "m": m, "h": h_fin, "conv": new_hist}
    return shard(y, "batch", None, "act_embed"), new_state


def apply_slstm_step(p, x, cfg: ModelConfig, state) -> Tuple[jax.Array, Dict]:
    y, new_state = apply_slstm_seq(p, x, cfg, state)
    return y, new_state
