"""The unified decoder / encoder-decoder model.

One implementation covers all ten assigned architectures: the layer stack is
``cfg.block_pattern`` tiled across ``num_layers``; full pattern repetitions
are executed under ``jax.lax.scan`` (params stacked on a leading `layers`
axis — keeps the HLO size O(pattern) instead of O(num_layers), which is what
makes the 64-layer 104B dry-run compile in minutes), remainder layers are
unrolled.

Three entry points per model:
  * ``forward_train``  — full-sequence teacher-forced logits.
  * ``prefill``        — same math, but fills and returns the decode cache.
  * ``decode_step``    — one token against the cache (the TPOT step).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import cache as cache_lib
from repro.models import flags
from repro.models import moe as moe_lib
from repro.models import recurrent as rec_lib
from repro.models.config import ModelConfig
from repro.models.layers import (
    Maker, apply_norm, embed_tokens, make_embedding, make_norm, shard,
    split_params, unembed,
)
from repro.models.mlp import apply_mlp, make_mlp


# ---------------------------------------------------------------------------
# construction
# ---------------------------------------------------------------------------

def _make_block(mk: Maker, cfg: ModelConfig, kind: str, *, decoder: bool) -> Dict:
    d = cfg.d_model
    if kind in ("attn", "local_attn"):
        p = {
            "norm1": make_norm(mk.fork(), d),
            "attn": attn_lib.make_attention(mk.fork(), cfg),
            "norm2": make_norm(mk.fork(), d),
            "mlp": (moe_lib.make_moe(mk.fork(), cfg) if cfg.is_moe
                    else make_mlp(mk.fork(), d, cfg.d_ff, cfg.mlp_gated)),
        }
        if cfg.parallel_block:
            del p["norm2"]  # single shared pre-norm (Cohere/GPT-J style)
        if cfg.is_encdec and decoder:
            p["norm_c"] = make_norm(mk.fork(), d)
            p["cross"] = attn_lib.make_attention(mk.fork(), cfg, cross=True)
        return p
    if kind == "ffn":
        return {
            "norm": make_norm(mk.fork(), d),
            "mlp": make_mlp(mk.fork(), d, cfg.d_ff, cfg.mlp_gated),
        }
    if kind == "rglru":
        return {
            "norm1": make_norm(mk.fork(), d),
            "rec": rec_lib.make_rglru_block(mk.fork(), cfg),
            "norm2": make_norm(mk.fork(), d),
            "mlp": make_mlp(mk.fork(), d, cfg.d_ff, cfg.mlp_gated),
        }
    if kind == "mlstm":
        return {"norm": make_norm(mk.fork(), d),
                "cell": rec_lib.make_mlstm_block(mk.fork(), cfg)}
    if kind == "slstm":
        return {"norm": make_norm(mk.fork(), d),
                "cell": rec_lib.make_slstm_block(mk.fork(), cfg)}
    raise ValueError(kind)


def _enc_cfg(cfg: ModelConfig) -> ModelConfig:
    """Encoder trunk config (dense MLP, full attention, own d_ff)."""
    return cfg.replace(
        block_pattern=("attn",),
        num_layers=cfg.num_encoder_layers,
        d_ff=cfg.encoder_d_ff or cfg.d_ff,
        num_experts=0, num_experts_per_tok=0,
        num_encoder_layers=0,
    )


def _make_stack(key: jax.Array, cfg: ModelConfig, *, decoder: bool):
    """Returns (params, axes) for a layer stack (scan groups + remainder)."""
    dtype = jnp.dtype(cfg.param_dtype)
    pattern = cfg.block_pattern
    n_groups, n_rest = cfg.layer_groups()
    keys = jax.random.split(key, 3)

    def build_group(k):
        mk = Maker(k, dtype)
        return {
            str(i): _make_block(mk.fork(), cfg, kind, decoder=decoder)
            for i, kind in enumerate(pattern)
        }

    params: Dict[str, Any] = {}
    axes: Dict[str, Any] = {}
    if n_groups > 0:
        gkeys = jax.random.split(keys[0], n_groups)
        params["groups"] = jax.vmap(
            lambda k: split_params(build_group(k))[0]
        )(gkeys)
        g_axes = split_params(build_group(keys[0]))[1]
        axes["groups"] = jax.tree.map(
            lambda ax: ("layers", *ax), g_axes,
            is_leaf=lambda l: isinstance(l, tuple) and all(
                isinstance(a, (str, type(None))) for a in l),
        )
    if n_rest > 0:
        mk = Maker(keys[1], dtype)
        rest = {
            str(i): _make_block(mk.fork(), cfg, kind, decoder=decoder)
            for i, kind in enumerate(pattern[:n_rest])
        }
        params["rest"], axes["rest"] = split_params(rest)
    fn, fn_axes = split_params({"final_norm": make_norm(Maker(keys[2], dtype), cfg.d_model)})
    params.update(fn)
    axes.update(fn_axes)
    return params, axes


def init(cfg: ModelConfig, key: jax.Array) -> Tuple[Dict, Dict]:
    """Build params + logical-axes trees."""
    cfg.validate()
    dtype = jnp.dtype(cfg.param_dtype)
    k_emb, k_dec, k_enc = jax.random.split(key, 3)
    emb_tree = {"embed": make_embedding(Maker(k_emb, dtype), cfg.vocab_size, cfg.d_model)}
    if not cfg.tie_embeddings:
        k_emb2 = jax.random.fold_in(k_emb, 1)
        emb_tree["lm_head"] = make_embedding(Maker(k_emb2, dtype), cfg.vocab_size, cfg.d_model)
    emb, emb_axes = split_params(emb_tree)
    params, axes = dict(emb), dict(emb_axes)
    dec_p, dec_a = _make_stack(k_dec, cfg, decoder=True)
    params["decoder"], axes["decoder"] = dec_p, dec_a
    if cfg.is_encdec:
        enc_p, enc_a = _make_stack(k_enc, _enc_cfg(cfg), decoder=False)
        params["encoder"], axes["encoder"] = enc_p, enc_a
    return params, axes


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------

def _apply_block_seq(
    p: Dict,
    cfg: ModelConfig,
    kind: str,
    x: jax.Array,
    positions: jax.Array,
    cache_entry: Optional[Dict],
    memory: Optional[jax.Array],
    *,
    causal: bool,
    fill_cache: bool,
    block_tables: Optional[jax.Array] = None,
    chunked: bool = False,
    chunk_valid: Optional[jax.Array] = None,
    overwrite_from: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[Dict]]:
    """Full-sequence block (train / prefill / encoder).

    ``chunked=True`` switches attention blocks to the chunked-prefill path
    (attend over the cache + the chunk instead of a self-contained prompt);
    recurrent and conv blocks already resume from the state carried in
    ``cache_entry``, so they need no chunk-specific handling.

    ``chunk_valid`` (B, S) bool marks per-row valid prefixes when ragged
    chunks are packed into one static-width batch (unified mixed step):
    attention masks pad keys and cache writes, recurrent/conv states take
    identity steps at pads.
    """
    new_entry: Optional[Dict] = None
    if kind in ("attn", "local_attn"):
        window = cfg.sliding_window if kind == "local_attn" else 0
        h = apply_norm(p["norm1"], x, cfg.norm_eps)
        if fill_cache:
            if chunked:
                a, self_cache = attn_lib.apply_attention_prefill_chunk(
                    p["attn"], h, cfg, positions, cache_entry["self"],
                    window=window, block_tables=block_tables,
                    valid=chunk_valid, overwrite_from=overwrite_from,
                )
            else:
                a, self_cache = attn_lib.apply_attention_prefill(
                    p["attn"], h, cfg, positions, cache_entry["self"],
                    window=window, block_tables=block_tables
                )
            new_entry = {"self": self_cache}
        else:
            a = attn_lib.apply_attention_train(
                p["attn"], h, cfg, positions, causal=causal, window=window
            )
        mlp_in = h if cfg.parallel_block else None
        x = x + a
        if "cross" in p:
            h = apply_norm(p["norm_c"], x, cfg.norm_eps)
            mem_kv = attn_lib.precompute_cross_kv(p["cross"], memory, cfg)
            if fill_cache and new_entry is not None:
                new_entry["cross_k"], new_entry["cross_v"] = mem_kv
            x = x + attn_lib.apply_cross_attention(p["cross"], h, cfg, mem_kv)
        if mlp_in is None:
            mlp_in = apply_norm(p["norm2"], x, cfg.norm_eps)
        moe_fn = (moe_lib.apply_moe_blocked if flags.moe_blocked()
                  else moe_lib.apply_moe)
        x = x + (moe_fn(p["mlp"], mlp_in, cfg) if cfg.is_moe
                 else apply_mlp(p["mlp"], mlp_in, cfg.mlp_act))
        return x, new_entry

    if kind == "ffn":
        h = apply_norm(p["norm"], x, cfg.norm_eps)
        x = x + apply_mlp(p["mlp"], h, cfg.mlp_act)
        return x, ({} if fill_cache else None)

    if kind == "rglru":
        h = apply_norm(p["norm1"], x, cfg.norm_eps)
        y, st = rec_lib.apply_rglru_seq(
            p["rec"], h, cfg, cache_entry if fill_cache else None,
            valid=chunk_valid if fill_cache else None,
        )
        x = x + y
        h = apply_norm(p["norm2"], x, cfg.norm_eps)
        x = x + apply_mlp(p["mlp"], h, cfg.mlp_act)
        return x, (st if fill_cache else None)

    if kind in ("mlstm", "slstm"):
        h = apply_norm(p["norm"], x, cfg.norm_eps)
        fn = rec_lib.apply_mlstm_seq if kind == "mlstm" else rec_lib.apply_slstm_seq
        y, st = fn(p["cell"], h, cfg, cache_entry if fill_cache else None,
                   valid=chunk_valid if fill_cache else None)
        return x + y, (st if fill_cache else None)

    raise ValueError(kind)


def _gate_entry(new_entry: Dict, old_entry: Dict,
                update_mask: Optional[jax.Array]) -> Dict:
    """Freeze masked-off rows of a per-slot state entry at their old value.

    Used by the decode path for recurrent/conv states: idle and mid-prefill
    slots run the (garbage) step math for shape stability, but their state
    must not advance — a chunked prefill may be building it concurrently.
    Leaves are (B, ...); scalar bookkeeping leaves pass through.
    """
    if update_mask is None:
        return new_entry
    def gate(new, old):
        if new.ndim == 0:
            return new
        m = update_mask.reshape((-1,) + (1,) * (new.ndim - 1))
        return jnp.where(m, new, old)
    return jax.tree.map(gate, new_entry, old_entry)


def _apply_block_decode(
    p: Dict,
    cfg: ModelConfig,
    kind: str,
    x: jax.Array,
    position: jax.Array,
    cache_entry: Dict,
    block_tables: Optional[jax.Array] = None,
    update_mask: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Dict]:
    if kind in ("attn", "local_attn"):
        window = cfg.sliding_window if kind == "local_attn" else 0
        h = apply_norm(p["norm1"], x, cfg.norm_eps)
        a, self_cache = attn_lib.apply_attention_decode(
            p["attn"], h, cfg, position, cache_entry["self"], window=window,
            block_tables=block_tables, update_mask=update_mask
        )
        new_entry = dict(cache_entry)
        new_entry["self"] = self_cache
        mlp_in = h if cfg.parallel_block else None
        x = x + a
        if "cross" in p:
            h = apply_norm(p["norm_c"], x, cfg.norm_eps)
            mem_kv = (cache_entry["cross_k"], cache_entry["cross_v"])
            x = x + attn_lib.apply_cross_attention(p["cross"], h, cfg, mem_kv)
        if mlp_in is None:
            mlp_in = apply_norm(p["norm2"], x, cfg.norm_eps)
        moe_fn = (moe_lib.apply_moe_blocked if flags.moe_blocked()
                  else moe_lib.apply_moe)
        x = x + (moe_fn(p["mlp"], mlp_in, cfg) if cfg.is_moe
                 else apply_mlp(p["mlp"], mlp_in, cfg.mlp_act))
        return x, new_entry

    if kind == "ffn":
        h = apply_norm(p["norm"], x, cfg.norm_eps)
        return x + apply_mlp(p["mlp"], h, cfg.mlp_act), {}

    if kind == "rglru":
        h = apply_norm(p["norm1"], x, cfg.norm_eps)
        y, st = rec_lib.apply_rglru_step(p["rec"], h, cfg, cache_entry)
        x = x + y
        h = apply_norm(p["norm2"], x, cfg.norm_eps)
        x = x + apply_mlp(p["mlp"], h, cfg.mlp_act)
        return x, _gate_entry(st, cache_entry, update_mask)

    if kind in ("mlstm", "slstm"):
        h = apply_norm(p["norm"], x, cfg.norm_eps)
        fn = rec_lib.apply_mlstm_step if kind == "mlstm" else rec_lib.apply_slstm_step
        y, st = fn(p["cell"], h, cfg, cache_entry)
        return x + y, _gate_entry(st, cache_entry, update_mask)

    raise ValueError(kind)


# ---------------------------------------------------------------------------
# stack application
# ---------------------------------------------------------------------------

def _apply_stack_seq(
    stack: Dict,
    cfg: ModelConfig,
    x: jax.Array,
    positions: jax.Array,
    cache: Optional[Dict],
    memory: Optional[jax.Array],
    *,
    causal: bool,
    remat: bool,
    block_tables: Optional[jax.Array] = None,
    chunked: bool = False,
    chunk_valid: Optional[jax.Array] = None,
    overwrite_from: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[Dict]]:
    pattern = cfg.block_pattern
    fill = cache is not None
    n_groups, n_rest = cfg.layer_groups()

    def group_body(x, group_params, group_cache):
        new_cache = {}
        for i, kind in enumerate(pattern):
            entry = group_cache[str(i)] if fill else None
            x, new_entry = _apply_block_seq(
                group_params[str(i)], cfg, kind, x, positions, entry, memory,
                causal=causal, fill_cache=fill, block_tables=block_tables,
                chunked=chunked, chunk_valid=chunk_valid,
                overwrite_from=overwrite_from,
            )
            if fill:
                new_cache[str(i)] = new_entry
        return x, (new_cache if fill else None)

    if remat:
        group_body = jax.checkpoint(
            group_body, policy=jax.checkpoint_policies.nothing_saveable
        )

    new_cache_tree: Dict[str, Any] = {}
    if n_groups > 0:
        def scan_fn(x, xs):
            gp, gc = xs
            x, nc = group_body(x, gp, gc if fill else None)
            return x, nc

        xs = (stack["groups"], cache["groups"] if fill else None)
        if not fill:
            xs = (stack["groups"], jnp.zeros((n_groups,), jnp.int32))
        if flags.unroll_scans():
            caches = []
            for g in range(n_groups):
                x, nc = scan_fn(x, jax.tree.map(lambda t: t[g], xs))
                caches.append(nc)
            group_caches = (jax.tree.map(lambda *ts: jnp.stack(ts), *caches)
                            if fill else None)
        else:
            x, group_caches = jax.lax.scan(scan_fn, x, xs)
        if fill:
            new_cache_tree["groups"] = group_caches
    if n_rest > 0:
        new_rest = {}
        for i, kind in enumerate(pattern[:n_rest]):
            entry = cache["rest"][str(i)] if fill else None
            x, new_entry = _apply_block_seq(
                stack["rest"][str(i)], cfg, kind, x, positions, entry, memory,
                causal=causal, fill_cache=fill, block_tables=block_tables,
                chunked=chunked, chunk_valid=chunk_valid,
                overwrite_from=overwrite_from,
            )
            if fill:
                new_rest[str(i)] = new_entry
        if fill:
            new_cache_tree["rest"] = new_rest
    x = apply_norm(stack["final_norm"], x, cfg.norm_eps)
    return x, (new_cache_tree if fill else None)


def _apply_stack_decode(
    stack: Dict,
    cfg: ModelConfig,
    x: jax.Array,
    position: jax.Array,
    cache: Dict,
    block_tables: Optional[jax.Array] = None,
    update_mask: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Dict]:
    pattern = cfg.block_pattern
    n_groups, n_rest = cfg.layer_groups()
    new_cache: Dict[str, Any] = {}
    if n_groups > 0:
        def scan_fn(x, xs):
            gp, gc = xs
            nc = {}
            for i, kind in enumerate(pattern):
                x, nc[str(i)] = _apply_block_decode(
                    gp[str(i)], cfg, kind, x, position, gc[str(i)],
                    block_tables, update_mask
                )
            return x, nc

        xs = (stack["groups"], cache["groups"])
        if flags.unroll_scans():
            caches = []
            for g in range(n_groups):
                x, nc = scan_fn(x, jax.tree.map(lambda t: t[g], xs))
                caches.append(nc)
            group_caches = jax.tree.map(lambda *ts: jnp.stack(ts), *caches)
        else:
            x, group_caches = jax.lax.scan(scan_fn, x, xs)
        new_cache["groups"] = group_caches
    if n_rest > 0:
        nr = {}
        for i, kind in enumerate(pattern[:n_rest]):
            x, nr[str(i)] = _apply_block_decode(
                stack["rest"][str(i)], cfg, kind, x, position,
                cache["rest"][str(i)], block_tables, update_mask
            )
        new_cache["rest"] = nr
    x = apply_norm(stack["final_norm"], x, cfg.norm_eps)
    return x, new_cache


# ---------------------------------------------------------------------------
# embedding frontends
# ---------------------------------------------------------------------------

def _embed_inputs(cfg: ModelConfig, params: Dict, batch: Dict) -> jax.Array:
    """Token embedding, with the VLM patch-prefix stub when configured."""
    x = embed_tokens(params["embed"], batch["tokens"], cfg.emb_scale, cfg.d_model)
    if cfg.num_vision_tokens > 0 and "vision_embeds" in batch:
        ve = batch["vision_embeds"].astype(x.dtype)  # (B, N_img, d) precomputed
        x = jnp.concatenate([ve, x], axis=1)
    return x


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------

def forward_train(
    cfg: ModelConfig, params: Dict, batch: Dict, *, remat: bool = True
) -> jax.Array:
    """Teacher-forced logits (B, S, vocab).

    batch: tokens (B, S) [+ vision_embeds (B, N, d)] [+ enc_embeds (B, T, d)].
    """
    x = _embed_inputs(cfg, params, batch)
    positions = jnp.broadcast_to(
        jnp.arange(x.shape[1], dtype=jnp.int32)[None], x.shape[:2]
    )
    memory = None
    if cfg.is_encdec:
        enc_x = batch["enc_embeds"].astype(x.dtype)
        enc_pos = jnp.broadcast_to(
            jnp.arange(enc_x.shape[1], dtype=jnp.int32)[None], enc_x.shape[:2]
        )
        memory, _ = _apply_stack_seq(
            params["encoder"], _enc_cfg(cfg), enc_x, enc_pos, None, None,
            causal=False, remat=remat,
        )
    x, _ = _apply_stack_seq(
        params["decoder"], cfg, x, positions, None, memory,
        causal=True, remat=remat,
    )
    return unembed(params.get("lm_head", params["embed"]), x, cfg.logit_softcap)


def param_axes(cfg: ModelConfig):
    """(param ShapeDtypeStruct tree, logical-axes tree) — no allocation."""
    captured = {}

    def f(key):
        params, axes = init(cfg, key)
        captured["axes"] = axes
        return params

    shapes = jax.eval_shape(f, jax.random.PRNGKey(0))
    return shapes, captured["axes"]


def init_cache(
    cfg: ModelConfig, batch: int, max_len: int, dtype,
    *, layout: str = "contiguous", block_size: int = 16,
    num_blocks: int = 0,
) -> Dict:
    """Decode cache for the decoder stack (stacked to mirror param groups).

    ``layout="paged"`` swaps full-context attention entries for global block
    pools (``num_blocks`` x ``block_size``; 0 -> worst-case sizing) shared
    by all slots and addressed through the caller's block tables.  Ring
    (sliding-window), recurrent, and cross-attention entries are identical
    in both layouts.
    """
    assert layout in ("contiguous", "paged"), layout
    pattern = cfg.block_pattern
    n_groups, n_rest = cfg.layer_groups()

    def entry(kind):
        c = cache_lib.init_block_cache(
            cfg, kind, batch, max_len, dtype,
            layout=layout, block_size=block_size, num_blocks=num_blocks)
        if kind in ("attn", "local_attn"):
            c = {"self": c}
            if cfg.is_encdec:
                t_mem = max_len // 2 if max_len > 1 else 1
                hd = cfg.resolved_head_dim
                c["cross_k"] = jnp.zeros((batch, t_mem, cfg.num_kv_heads, hd), dtype)
                c["cross_v"] = jnp.zeros((batch, t_mem, cfg.num_kv_heads, hd), dtype)
        return c

    cache: Dict[str, Any] = {}
    if n_groups > 0:
        cache["groups"] = {
            str(i): jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (n_groups, *a.shape)).copy(),
                entry(kind),
            )
            for i, kind in enumerate(pattern)
        }
    if n_rest > 0:
        cache["rest"] = {str(i): entry(kind) for i, kind in enumerate(pattern[:n_rest])}
    return cache


def prefill(
    cfg: ModelConfig, params: Dict, batch: Dict, cache: Dict, *,
    remat: bool = False, block_tables: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Dict]:
    """Process the prompt, fill the cache; returns last-position logits.

    For a paged cache, ``block_tables`` (B, max_blocks) names the pool
    blocks each row's prompt K/V scatters into.
    """
    x = _embed_inputs(cfg, params, batch)
    positions = jnp.broadcast_to(
        jnp.arange(x.shape[1], dtype=jnp.int32)[None], x.shape[:2]
    )
    memory = None
    if cfg.is_encdec:
        enc_x = batch["enc_embeds"].astype(x.dtype)
        enc_pos = jnp.broadcast_to(
            jnp.arange(enc_x.shape[1], dtype=jnp.int32)[None], enc_x.shape[:2]
        )
        memory, _ = _apply_stack_seq(
            params["encoder"], _enc_cfg(cfg), enc_x, enc_pos, None, None,
            causal=False, remat=remat,
        )
    x, new_cache = _apply_stack_seq(
        params["decoder"], cfg, x, positions, cache, memory,
        causal=True, remat=remat, block_tables=block_tables,
    )
    logits = unembed(params.get("lm_head", params["embed"]), x[:, -1:],
                     cfg.logit_softcap)[:, 0]
    return logits, new_cache


def prefill_chunk(
    cfg: ModelConfig, params: Dict, batch: Dict, cache: Dict,
    start: jax.Array, *, block_tables: Optional[jax.Array] = None,
    lengths: Optional[jax.Array] = None,
    overwrite_from: Optional[jax.Array] = None,
    all_logits: bool = False,
) -> Tuple[jax.Array, Dict]:
    """Process one prompt chunk (positions ``start..start+C-1``) against a
    cache already holding chunks for positions ``0..start-1``.

    Attention blocks attend over the cached earlier chunks plus the chunk
    itself (causal); recurrent/conv blocks resume from their carried state.
    ``start`` may be a traced scalar — or, for the unified mixed-batch step,
    a per-row (B,) vector — so one compiled executable serves every chunk
    offset of a given chunk width.  Returns the chunk's last-position
    logits (only meaningful for the final chunk) and the updated cache.

    ``lengths`` (B,) int32, when given, marks how many of each row's C
    columns are real tokens (ragged rows packed to one static width):
    pad columns write nothing to the cache, recurrent states take identity
    steps, and the returned logits come from each row's *last valid*
    position (rows with ``lengths == 0`` return garbage logits and leave
    their cache rows untouched).  For a VLM config, pass ``vision_embeds``
    only with the ``start == 0`` chunk and offset later chunk starts by
    ``num_vision_tokens`` — mirroring the prefix handling of ``prefill``;
    ``lengths`` is not supported together with a vision prefix.

    The speculative verify step reuses this multi-token path to score a
    draft window against the live cache: ``overwrite_from`` (B,) hides
    stale contiguous cache entries at positions >= the row's value (a
    previous window's rejected suffix shares the new window's positions —
    see ``apply_attention_prefill_chunk``), and ``all_logits=True``
    returns the full per-position logits (B, C, vocab) instead of each
    row's last-valid-position row — verification needs the target
    distribution *at every window position*, not just the final one.
    """
    x = _embed_inputs(cfg, params, batch)
    start = jnp.asarray(start, jnp.int32)
    if start.ndim == 0:
        positions = start + jnp.arange(x.shape[1], dtype=jnp.int32)[None]
    else:
        positions = start[:, None] + jnp.arange(x.shape[1], dtype=jnp.int32)[None]
    positions = jnp.broadcast_to(positions, x.shape[:2])
    valid = None
    if lengths is not None:
        lengths = jnp.asarray(lengths, jnp.int32)
        valid = jnp.arange(x.shape[1], dtype=jnp.int32)[None] < lengths[:, None]
    memory = None
    if cfg.is_encdec:
        enc_x = batch["enc_embeds"].astype(x.dtype)
        enc_pos = jnp.broadcast_to(
            jnp.arange(enc_x.shape[1], dtype=jnp.int32)[None], enc_x.shape[:2]
        )
        memory, _ = _apply_stack_seq(
            params["encoder"], _enc_cfg(cfg), enc_x, enc_pos, None, None,
            causal=False, remat=False,
        )
    x, new_cache = _apply_stack_seq(
        params["decoder"], cfg, x, positions, cache, memory,
        causal=True, remat=False, block_tables=block_tables, chunked=True,
        chunk_valid=valid, overwrite_from=overwrite_from,
    )
    if all_logits:
        logits = unembed(params.get("lm_head", params["embed"]), x,
                         cfg.logit_softcap)
        return logits, new_cache
    if lengths is None:
        x_last = x[:, -1:]
    else:
        idx = jnp.clip(lengths - 1, 0, x.shape[1] - 1)
        x_last = jnp.take_along_axis(x, idx[:, None, None], axis=1)
    logits = unembed(params.get("lm_head", params["embed"]), x_last,
                     cfg.logit_softcap)[:, 0]
    return logits, new_cache


def decode_step(
    cfg: ModelConfig, params: Dict, token: jax.Array, position: jax.Array,
    cache: Dict, block_tables: Optional[jax.Array] = None,
    update_mask: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Dict]:
    """One decode step.  token (B, 1) int32; position scalar or (B,) int32.
    ``block_tables`` (B, max_blocks) int32 is required for paged caches.
    ``update_mask`` (B,) bool freezes cache/state writes of masked-off rows
    (idle or mid-chunked-prefill slots in the serving engine)."""
    position = jnp.broadcast_to(
        jnp.asarray(position, jnp.int32), (token.shape[0],))
    x = embed_tokens(params["embed"], token, cfg.emb_scale, cfg.d_model)
    x, new_cache = _apply_stack_decode(params["decoder"], cfg, x, position,
                                       cache, block_tables, update_mask)
    logits = unembed(params.get("lm_head", params["embed"]), x, cfg.logit_softcap)[:, 0]
    return logits, new_cache
