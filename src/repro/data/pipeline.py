"""Host-side input pipeline: background prefetch of next batches so host
data prep overlaps device compute (the standard double-buffering trick).
"""

from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Iterator, Optional


class Prefetcher:
    """Wrap an iterator with an N-deep background prefetch queue."""

    _SENTINEL = object()

    def __init__(self, it: Iterator[Any], depth: int = 2):
        self._it = it
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._stopped = threading.Event()
        self._thread.start()

    def _fill(self) -> None:
        try:
            for item in self._it:
                if self._stopped.is_set():
                    return
                self._q.put(item)
        except BaseException as e:  # surfaced on next()
            self._err = e
        finally:
            self._q.put(self._SENTINEL)

    def __iter__(self) -> "Prefetcher":
        return self

    def __next__(self) -> Any:
        item = self._q.get()
        if item is self._SENTINEL:
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item

    def close(self) -> None:
        self._stopped.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
