"""Deterministic synthetic LM data.

Sample-exact resumability: batch ``i`` is a pure function of (seed, i, rank),
so restarts and elastic re-runs reproduce the identical stream without any
state beyond the step counter.  The token distribution is Zipfian with a
small amount of local structure (bigram copy) so losses actually decrease
during the example training runs.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass
class SyntheticConfig:
    vocab_size: int
    seq_len: int
    batch_size: int            # per-process batch
    seed: int = 0
    zipf_a: float = 1.2
    copy_prob: float = 0.3     # p(token_t = token_{t-2}): learnable structure


class SyntheticDataset:
    def __init__(self, cfg: SyntheticConfig):
        self.cfg = cfg
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        probs = ranks ** (-cfg.zipf_a)
        self._probs = probs / probs.sum()

    def batch_at(self, index: int, rank: int = 0) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, rank, index]))
        shape = (cfg.batch_size, cfg.seq_len + 1)
        toks = rng.choice(cfg.vocab_size, size=shape, p=self._probs)
        if cfg.copy_prob > 0:
            copy = rng.random(shape) < cfg.copy_prob
            copy[:, :2] = False
            shifted = np.roll(toks, 2, axis=1)
            toks = np.where(copy, shifted, toks)
        toks = toks.astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        i = 0
        while True:
            yield self.batch_at(i)
            i += 1


def batch_for_model(cfg: ModelConfig, data: Dict[str, np.ndarray],
                    rng: Optional[np.random.Generator] = None) -> Dict:
    """Attach modality-stub inputs (vision/audio) required by the config."""
    rng = rng or np.random.default_rng(0)
    out = dict(data)
    B = data["tokens"].shape[0]
    if cfg.num_vision_tokens:
        out["vision_embeds"] = rng.standard_normal(
            (B, cfg.num_vision_tokens, cfg.d_model), dtype=np.float32) * 0.1
    if cfg.is_encdec:
        T = max(data["tokens"].shape[1] // 2, 1)
        out["enc_embeds"] = rng.standard_normal(
            (B, T, cfg.d_model), dtype=np.float32) * 0.1
    return out
