"""Binary token-file dataset: flat little-endian token stream + json header.

Format (``.tokbin`` + ``.tokbin.json``): the header records dtype
(uint16/uint32), token count, and vocab size; the body is the raw token
array.  Readers are sharded per data-parallel rank by strided sequence
assignment, and addressing is (epoch, offset)-based so the
``fault.RunPosition`` checkpoint metadata resumes the stream sample-exactly.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


def write_tokenbin(path: str, tokens: np.ndarray, vocab_size: int) -> None:
    dtype = np.uint16 if vocab_size <= np.iinfo(np.uint16).max + 1 else np.uint32
    arr = np.ascontiguousarray(tokens.astype(dtype))
    with open(path, "wb") as f:
        f.write(arr.tobytes())
    with open(path + ".json", "w") as f:
        json.dump({"dtype": str(np.dtype(dtype)), "num_tokens": int(arr.size),
                   "vocab_size": int(vocab_size)}, f)


@dataclasses.dataclass
class TokenBinDataset:
    path: str
    seq_len: int
    batch_size: int       # per-rank batch
    rank: int = 0
    world: int = 1

    def __post_init__(self):
        with open(self.path + ".json") as f:
            self.header = json.load(f)
        self._data = np.memmap(self.path, dtype=np.dtype(self.header["dtype"]),
                               mode="r")
        self.num_sequences = (self.header["num_tokens"] - 1) // self.seq_len
        assert self.num_sequences >= self.batch_size * self.world, (
            f"{self.path}: {self.num_sequences} sequences < "
            f"batch {self.batch_size} x world {self.world}")

    @property
    def batches_per_epoch(self) -> int:
        return self.num_sequences // (self.batch_size * self.world)

    def _sequence(self, idx: int) -> np.ndarray:
        start = idx * self.seq_len
        return np.asarray(self._data[start: start + self.seq_len + 1], np.int32)

    def batch_at(self, epoch: int, offset: int) -> Dict[str, np.ndarray]:
        """Deterministic batch for (epoch, offset); per-epoch shuffle."""
        rng = np.random.default_rng(np.random.SeedSequence([epoch, 7]))
        perm = rng.permutation(self.num_sequences)
        base = offset * self.batch_size * self.world + self.rank * self.batch_size
        idxs = perm[base: base + self.batch_size]
        seqs = np.stack([self._sequence(i) for i in idxs])
        return {"tokens": seqs[:, :-1], "labels": seqs[:, 1:]}

    def iter_from(self, epoch: int = 0, offset: int = 0
                  ) -> Iterator[Tuple[int, int, Dict[str, np.ndarray]]]:
        while True:
            while offset < self.batches_per_epoch:
                yield epoch, offset, self.batch_at(epoch, offset)
                offset += 1
            epoch += 1
            offset = 0
