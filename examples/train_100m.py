"""End-to-end training driver example: a ~100M-parameter llama-family model
trained for a few hundred steps on synthetic data, with checkpointing,
preemption safety, straggler tracking, and ELANA energy accounting.

    PYTHONPATH=src python examples/train_100m.py              # full run
    PYTHONPATH=src python examples/train_100m.py --tiny       # CI-speed run

On the CPU dev rig the full ~100M config runs at a few seconds/step; on
real hardware point ``--mesh production`` at a pod.
"""

import argparse
import json

from repro.configs import get_config
from repro.launch.train import build_argparser, train
from repro.models.config import ModelConfig

# ~100M params: 12 layers, d=768, llama-style (tied embeddings)
MODEL_100M = ModelConfig(
    name="llama-100m", family="dense",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=4, head_dim=64,
    d_ff=2048, vocab_size=32_000, tie_embeddings=True,
    dtype="float32", param_dtype="float32",
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="reduced model + 30 steps (smoke/CI)")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/elana_train_100m")
    args = ap.parse_args()

    import repro.configs as configs

    # register the example model so the generic driver can find it
    name = "llama-100m"
    if args.tiny:
        cfg = MODEL_100M.replace(num_layers=4, d_model=128, num_heads=4,
                                 num_kv_heads=2, head_dim=32, d_ff=256,
                                 vocab_size=512)
    else:
        cfg = MODEL_100M
    import sys
    import types

    mod = types.ModuleType("repro.configs.llama_100m")
    mod.CONFIG = cfg
    mod.SMOKE = cfg
    sys.modules["repro.configs.llama_100m"] = mod
    configs._MODULES[name] = "llama_100m"

    steps = 30 if args.tiny else args.steps
    targs = build_argparser().parse_args([
        "--arch", name, "--steps", str(steps),
        "--batch", "8", "--seq-len", "128" if not args.tiny else "64",
        "--lr", "3e-3", "--warmup", "20",
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "100",
        "--energy", "--log-every", "10",
    ])
    out = train(targs)
    print(json.dumps(out, indent=2))
    assert out["loss_last"] < out["loss_first"], "loss did not decrease!"
    print(f"\nloss {out['loss_first']:.3f} -> {out['loss_last']:.3f} over "
          f"{out['steps']} steps; checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
