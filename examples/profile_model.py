"""Full profiling session (paper §2 end-to-end), including the custom-model
hook — the JAX analogue of overriding ``_build_model_and_tokenizer``.

    PYTHONPATH=src python examples/profile_model.py [--arch qwen1.5-0.5b]
"""

import argparse
import json

import jax

from repro.core import energy as energy_lib
from repro.core.profiler import Elana


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=8)
    args = ap.parse_args()

    # ---- option A: registry model --------------------------------------
    e = Elana(args.arch, smoke=True)

    # ---- option B: your own model, ELANA unchanged ----------------------
    # from repro.models import model as model_lib
    # def builder():
    #     cfg = my_custom_config()                 # any ModelConfig
    #     params = my_load_quantized_weights(cfg)  # e.g. compressed models
    #     return cfg, params
    # e = Elana(builder=builder)

    print("== size =="); print(e.size_report().fmt())
    print("\n== cache =="); print(e.cache_report(2, 256).fmt("MB"))

    print("\n== measured latency + energy (10 Hz ProcStat sampler) ==")
    m = e.measure(batch=1, prompt_len=args.prompt_len, gen_len=args.gen_len,
                  iters=3, power_reader=energy_lib.ProcStatReader())
    print(json.dumps(m, indent=2))

    print("\n== estimated on the paper's platforms ==")
    for hw in ("a6000", "jetson-agx-thor", "jetson-orin-nano", "tpu-v5e"):
        full = Elana(args.arch)  # full config for the estimator
        est = full.estimate(hardware=hw, batch=1, prompt_len=512, gen_len=512)
        print(f"{hw:18s} TTFT {est.ttft.latency_s*1e3:8.1f} ms  "
              f"TPOT {est.tpot.latency_s*1e3:7.2f} ms  "
              f"J/Tok {est.tpot.joules:6.2f}  [{est.tpot.bound}]")

    path = f"trace_{args.arch.replace('.', '_')}.json"
    s = Elana(args.arch).trace(path, phase="decode", seq_len=1024)
    print(f"\nwrote {path} — {json.dumps(s, indent=2)}")


if __name__ == "__main__":
    main()
