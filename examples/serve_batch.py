"""Batched serving example: submit a mixed batch of requests to the engine,
stream them through slot-based continuous batching, report ELANA metrics.

    PYTHONPATH=src python examples/serve_batch.py
"""

import json

import jax
import numpy as np

from repro.configs import get_config
from repro.models import model as model_lib
from repro.serving.engine import ServingEngine
from repro.serving.sampling import SamplingParams


def main() -> None:
    cfg = get_config("tinyllama-1.1b", smoke=True)
    params, _ = model_lib.init(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, max_batch=4, max_len=128,
                           prompt_bucket=16)

    rng = np.random.default_rng(0)
    print("submitting 10 requests (prompt lengths 4..40, 8-24 new tokens)")
    for i in range(10):
        prompt = rng.integers(0, cfg.vocab_size, int(rng.integers(4, 40)))
        engine.submit(prompt, SamplingParams(
            temperature=0.7 if i % 2 else 0.0,   # mixed greedy/sampled
            top_k=20, max_new_tokens=int(rng.integers(8, 24))))

    finished = engine.run()
    print(f"finished {len(finished)} requests")
    for r in finished[:3]:
        print(f"  req {r.uid}: prompt {len(r.prompt)} toks -> "
              f"{len(r.output_tokens)} new, TTFT {r.ttft_s*1e3:.0f} ms, "
              f"TPOT {r.tpot_s*1e3:.0f} ms")
    print("\nELANA request metrics:")
    print(json.dumps(engine.latency_summary(), indent=2))


if __name__ == "__main__":
    main()
