"""Quickstart: the ELANA workflow in ten lines.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core.profiler import Elana

# Any registered architecture (see `elana archs`); full config = analytic
# profiling only, no weights are materialized.
e = Elana("llama3.1-8b")

print(e.size_report().fmt())                       # §2.2 model size
print()
print(e.cache_report(batch=128, seq_len=2048).fmt())  # §2.2 KV cache
print()

# §2.3/2.4 estimator mode: latency + energy on a target platform
est = e.estimate(hardware="a6000", batch=1, prompt_len=512, gen_len=512)
print(f"A6000 bsize=1 L=512+512:  TTFT {est.ttft.latency_s*1e3:.1f} ms "
      f"({est.ttft.joules:.1f} J)  TPOT {est.tpot.latency_s*1e3:.2f} ms "
      f"({est.tpot.joules:.2f} J/tok)  [{est.tpot.bound}-bound]")

est = e.estimate(hardware="tpu-v5e", n_devices=16, batch=8,
                 prompt_len=2048, gen_len=512)
print(f"TPU v5e x16 bsize=8:      TTFT {est.ttft.latency_s*1e3:.1f} ms   "
      f"TPOT {est.tpot.latency_s*1e3:.2f} ms  [{est.tpot.bound}-bound]")

# §2.5 kernel-level timeline for Perfetto
summary = e.trace("quickstart_trace.json", hardware="tpu-v5e", phase="decode",
                  seq_len=2048)
print(f"\nwrote quickstart_trace.json (open at https://ui.perfetto.dev) — "
      f"{summary['memory_bound_frac']*100:.0f}% of decode time is memory-bound")

# Measured mode runs real wall-clock on whatever backend exists — use the
# reduced config on this CPU rig:
m = Elana("qwen1.5-0.5b", smoke=True).measure(batch=1, prompt_len=32, gen_len=8)
print(f"\nmeasured (reduced qwen1.5-0.5b on CPU): "
      f"TTFT {m['ttft_ms']:.1f} ms, TPOT {m['tpot_ms']:.1f} ms")
