"""Tensor-parallel sharded-serving benchmark entry point.

The section itself lives in ``serving_bench`` (it shares that module's
engine/workload plumbing); this thin module gives it its own harness key
so the bench-smoke CI leg can run just the sharded row under
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` — the full
serving suite runs on the default single-device host, where the section
skips itself.
"""

from __future__ import annotations

from typing import List

import jax

from benchmarks import serving_bench
from repro.configs import get_config
from repro.models import model as model_lib


def run(csv_rows: List[str]) -> str:
    cfg = get_config(serving_bench.ARCH, smoke=True)
    params, axes = model_lib.init(cfg, jax.random.PRNGKey(0))
    return serving_bench._sharded_section(cfg, params, axes, csv_rows)
