"""Paper Table 4 reproduction: Jetson AGX Thor / Orin Nano (estimator mode).

Edge power is GPU-rail-only (jtop), modeled per DESIGN.md §2.  The paper's
Thor TTLT rows are internally inconsistent with their own TTFT+TPOT
decomposition (see EXPERIMENTS §Paper-validation); we report our
decomposition-consistent estimates next to the published values.
"""

from __future__ import annotations

import time
from typing import List

from repro.core import report
from repro.core.profiler import Elana

PAPER_THOR = {  # bsize=1, L=512+512
    "llama3.1-8b": (147.49, 7.40, 97.60, 1.27),
    "qwen2.5-7b": (115.27, 6.39, 61.22, 0.88),
    "nemotron-h-8b": (147.29, 7.08, 101.73, 1.29),
}
PAPER_NANO = {  # bsize=1: (L, TTFT, J/Prom, TPOT, J/Tok)
    ("llama3.2-1b", 256): (142.92, 0.42, 48.73, 0.06),
    ("qwen2.5-1.5b", 256): (249.89, 0.80, 60.66, 0.08),
    ("llama3.2-1b", 512): (278.0, 1.12, 48.69, 0.06),
    ("qwen2.5-1.5b", 512): (359.30, 1.53, 61.43, 0.08),
}


def run(csv_rows: List[str]) -> str:
    lines = ["## Table 4: AGX Thor 128GB, bsize=1, L=512+512 (estimator vs paper)"]
    rows = []
    for arch, exp in PAPER_THOR.items():
        t0 = time.perf_counter()
        r = Elana(arch).estimate(hardware="jetson-agx-thor", batch=1,
                                 prompt_len=512, gen_len=512).row()
        ours = (r["TTFT_ms"], r["J_per_prompt"], r["TPOT_ms"], r["J_per_token"])
        rels = [abs(o - p) / p for o, p in zip(ours, exp)]
        rows.append({
            "Model": arch,
            "TTFT": round(ours[0], 1), "pTTFT": exp[0],
            "J/Prom": round(ours[1], 2), "pJ/Prom": exp[1],
            "TPOT": round(ours[2], 1), "pTPOT": exp[2],
            "J/Tok": round(ours[3], 2), "pJ/Tok": exp[3],
        })
        csv_rows.append(f"table4_thor_{arch},{(time.perf_counter()-t0)*1e6:.0f},"
                        f"tpot_relerr={rels[2]:.3f}")
    lines.append(report.to_markdown(rows))

    lines.append("\n## Table 4: Orin Nano 8GB, bsize=1 (estimator vs paper)")
    rows = []
    for (arch, L), exp in PAPER_NANO.items():
        r = Elana(arch).estimate(hardware="jetson-orin-nano", batch=1,
                                 prompt_len=L, gen_len=L).row()
        ours = (r["TTFT_ms"], r["J_per_prompt"], r["TPOT_ms"], r["J_per_token"])
        rels = [abs(o - p) / p for o, p in zip(ours, exp)]
        rows.append({
            "Model": f"{arch} L={L}",
            "TTFT": round(ours[0], 1), "pTTFT": exp[0],
            "J/Prom": round(ours[1], 2), "pJ/Prom": exp[1],
            "TPOT": round(ours[2], 1), "pTPOT": exp[2],
            "J/Tok": round(ours[3], 3), "pJ/Tok": exp[3],
        })
        csv_rows.append(f"table4_nano_{arch}_L{L},0,tpot_relerr={rels[2]:.3f}")
    lines.append(report.to_markdown(rows))
    return "\n".join(lines)


if __name__ == "__main__":
    csv: List[str] = []
    print(run(csv))
    print("\n".join(csv))
