"""Speculative-decoding benchmark entry point.

The section itself lives in ``serving_bench`` (it shares that module's
engine/workload plumbing); this thin module gives it its own harness key
so ``--only speculative`` runs just the speculative row — without the
full serving suite re-running it.
"""

from __future__ import annotations

from typing import List

import jax

from benchmarks import serving_bench
from repro.configs import get_config
from repro.models import model as model_lib


def run(csv_rows: List[str]) -> str:
    cfg = get_config(serving_bench.ARCH, smoke=True)
    params, _ = model_lib.init(cfg, jax.random.PRNGKey(0))
    return serving_bench._speculative_section(cfg, params, csv_rows)
