"""Serving decode-loop benchmark: fused device-resident step (contiguous,
donated, and paged KV layouts) vs the legacy per-slot host loop, plus an
engine-level KV-memory comparison under a short-heavy workload.

The legacy path (the seed engine's ``_decode_once``) ran one jitted decode,
then for every slot dispatched a separate ``sample`` call and synced
``int(t[0])`` to the host — O(batch) device round-trips per step.  The
fused path (``serving.step.make_decode_sample_step``) samples all slots,
advances positions/budgets and detects finishes inside one jitted call,
then syncs a single packed (3, B) array.  Decode steps/sec should improve
measurably from ``max_batch >= 4`` on CPU.

Two regression guards ride along:

* **Donation** (``maybe_donate``): donating the cache/state buffers into
  the fused step must not cost throughput — asserted at >= 0.75x the
  non-donated fused rate (generous bound; donation is a no-op on CPU).
* **Paged KV**: the block-pool layout must stay within striking distance
  of the contiguous fused path (reported as a ratio), while the engine
  section shows the point of paging — peak KV bytes actually allocated for
  a short-heavy mixed-length workload vs the contiguous worst case.
* **Chunked prefill / TTFT interference**: while a long prompt admits,
  the p95 inter-token gap of in-flight decode slots must be no worse with
  chunking than with whole-prompt admission (and should improve: chunking
  bounds the per-step prompt work a decode token waits on).
* **Prefix caching**: a warm shared-prefix request (prefix blocks
  resident from an earlier sharer) must reach its first token >= 2x
  faster than a cold one — it prefills only the suffix tail.
* **Pool overcommit**: with the paged pool capped at ~50% of the worst
  case on a bursty trace, ``preemption="recompute"`` must still complete
  every request (preempting/recomputing as the pool breathes) with
  goodput within 2x of the uncontended full-pool run.
"""

from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import report
from repro.models import cache as cache_lib
from repro.models import model as model_lib
from repro.serving.engine import ServingEngine, _percentile
from repro.serving.sampling import SamplingParams, sample
from repro.serving.step import (init_slot_state, make_decode_sample_step,
                                maybe_donate)
from repro.serving.workload import (bursty_trace, interference_trace,
                                    lookup_friendly_trace)

ARCH = "qwen1.5-0.5b"
BATCHES = (1, 4, 8)
MAX_LEN = 128
BLOCK_SIZE = 16
STEPS = 30
WARMUP = 3


def _per_slot_reference_steps(decode, params, cache, B, n_steps, params_s):
    """The seed engine's decode loop: jitted decode + per-slot host sampling."""
    next_tokens = np.zeros((B, 1), np.int32)
    positions = np.full(B, 16, np.int64)
    key = jax.random.PRNGKey(0)
    t0 = time.perf_counter()
    for _ in range(n_steps):
        tok = jnp.asarray(next_tokens)
        pos = jnp.asarray(positions, jnp.int32)
        logits, cache = decode(params, tok, pos, cache)
        key, k = jax.random.split(key)
        for slot in range(B):
            t = sample(logits[slot:slot + 1], params_s,
                       jax.random.fold_in(k, slot))
            next_tokens[slot, 0] = int(t[0])      # per-slot host sync
            positions[slot] += 1
    jax.block_until_ready(logits)
    return time.perf_counter() - t0, cache


def _make_state(B, params_s, tables=None):
    state = init_slot_state(B, max_blocks=0 if tables is None
                            else tables.shape[1])
    state["active"] = jnp.ones((B,), jnp.bool_)
    state["positions"] = jnp.full((B,), 16, jnp.int32)
    state["remaining"] = jnp.full((B,), 10 ** 6, jnp.int32)
    state["temperature"] = jnp.full((B,), params_s.temperature, jnp.float32)
    state["top_k"] = jnp.full((B,), params_s.top_k, jnp.int32)
    if tables is not None:
        state["block_tables"] = tables
    return state


def _fused_steps(step, params, cache, B, n_steps, params_s, tables=None):
    state = _make_state(B, params_s, tables)
    t0 = time.perf_counter()
    for _ in range(n_steps):
        state, cache, out = step(params, state, cache)
        np.asarray(out)                           # the single host sync
    return time.perf_counter() - t0, cache


def _time_fused(step, cfg, params, B, params_s, *, layout="contiguous",
                repeats=3):
    """Warmup + best-of-``repeats`` timed runs (suppresses scheduler noise),
    each on a fresh cache (donation-safe)."""
    mk = lambda: model_lib.init_cache(cfg, B, MAX_LEN, jnp.dtype(cfg.dtype),
                                      layout=layout, block_size=BLOCK_SIZE)
    tables = None
    if layout == "paged":
        nb = MAX_LEN // BLOCK_SIZE
        tables = jnp.asarray(  # slot s owns blocks [1 + s*nb, 1 + (s+1)*nb)
            1 + np.arange(B * nb, dtype=np.int32).reshape(B, nb))
    _fused_steps(step, params, mk(), B, WARMUP, params_s, tables)
    best = min(_fused_steps(step, params, mk(), B, STEPS, params_s, tables)[0]
               for _ in range(repeats))
    return STEPS / best


def _engine_kv_section(cfg, params, csv_rows: List[str]) -> str:
    """Short-heavy mixed-length workload: paged peak KV bytes vs the
    contiguous worst case (the 2x-minimum saving the paging PR targets)."""
    rng = np.random.default_rng(0)
    plens = [int(n) for n in
             np.clip(rng.lognormal(np.log(20.0), 0.6, 12), 4, 192)]
    engines = {}
    for layout in ("contiguous", "paged"):
        eng = ServingEngine(cfg, params, max_batch=4, max_len=256,
                            prompt_bucket=16, cache_layout=layout,
                            kv_block_size=BLOCK_SIZE)
        for p in plens:
            eng.submit(rng.integers(0, cfg.vocab_size, p),
                       SamplingParams(max_new_tokens=8))
        eng.run()
        engines[layout] = eng
    worst = engines["contiguous"].kv_bytes_worst_case
    paged = engines["paged"].kv_bytes_in_use(peak=True)
    saving = worst / max(paged, 1)
    assert saving >= 2.0, (
        f"paged KV allocated {paged}B vs contiguous worst case {worst}B — "
        f"expected >= 2x saving for a short-heavy workload, got {saving:.2f}x")
    csv_rows.append(f"serving_paged_kv_bytes,{paged},saving={saving:.2f}x")
    md = report.to_markdown([{
        "workload": "12 reqs, lognormal prompts (mean~20), max_new=8",
        "contiguous worst case": f"{worst / 1e6:.2f} MB",
        "paged peak allocated": f"{paged / 1e6:.2f} MB",
        "saving": f"{saving:.1f}x",
    }])
    return ("## Engine KV memory: paged blocks-in-use vs contiguous "
            f"worst case\n\n{md}")


def _interference_p95(cfg, params, *, prefill_chunk: int,
                      windows: int = 6) -> float:
    """p95 inter-token gap (s) of in-flight decode slots while one long
    prompt admits; best of ``windows`` admissions (suppresses scheduler
    noise, like the best-of-repeats decode timings above).

    The engine decodes every active slot once per ``step()``, so the
    wall-clock duration of each engine step during the admission window
    *is* the victims' inter-token gap for that token.  The scenario runs
    once as a warm-up (compiles the prefill/chunk shapes); then, with the
    victims decoding throughout, a long prompt is admitted ``windows``
    times and the steps up to each first token are timed.
    """
    max_len, long_plen = 512, 448
    arrivals = interference_trace(cfg.vocab_size, long_plen=long_plen)
    victims, long_arr = arrivals[:-1], arrivals[-1]
    eng = ServingEngine(cfg, params, max_batch=4, max_len=max_len,
                        prompt_bucket=64, prefill_chunk=prefill_chunk)
    # warm-up: compile the victim-bucket prefill, chunk/long-prefill and
    # decode shapes outside the timed windows
    eng.submit(long_arr.prompt, SamplingParams(max_new_tokens=1))
    eng.submit(victims[0].prompt, SamplingParams(max_new_tokens=1))
    eng.run()
    eng.finished.clear()

    for a in victims:
        eng.submit(a.prompt, a.params)
    for _ in range(3):  # victims admitted and decoding
        eng.step()
    p95s = []
    for _ in range(windows):
        eng.submit(long_arr.prompt, long_arr.params)
        long_req = eng.queue[-1]
        gaps = []
        while long_req.first_token_time == 0.0 and len(gaps) < 200:
            t0 = time.perf_counter()
            eng.step()
            gaps.append(time.perf_counter() - t0)
        assert long_req.first_token_time > 0.0, "long prompt never admitted"
        p95s.append(_percentile(gaps, 95))
        # drain the long request so its slot frees for the next window
        # (the victims keep decoding: their budgets outlast every window)
        for _ in range(200):
            if all(s is None or s.uid != long_req.uid for s in eng.slots):
                break
            eng.step()
    return min(p95s)


def _interference_section(cfg, params, csv_rows: List[str]) -> str:
    """TTFT-interference row: p95 in-flight TPOT during a long-prompt
    admission, whole-prompt vs chunked admission."""
    p95 = {
        label: _interference_p95(cfg, params, prefill_chunk=chunk)
        for label, chunk in (("unchunked", 0), ("chunked", 64))
    }
    ratio = p95["unchunked"] / max(p95["chunked"], 1e-9)
    # regression gate: chunking must not make the interference worse
    # (slack for CI timer noise); the reported ratio shows the win
    assert p95["chunked"] <= 1.15 * p95["unchunked"], (
        f"chunked prefill worsened p95 in-flight TPOT under admission: "
        f"{p95['chunked'] * 1e3:.2f}ms vs {p95['unchunked'] * 1e3:.2f}ms")
    csv_rows.append(
        f"serving_chunked_interference_p95,{p95['chunked'] * 1e6:.1f},"
        f"x{ratio:.2f}_vs_unchunked")
    md = report.to_markdown([{
        "scenario": "3 victims decoding, 448-token prompt admits "
                    "(chunk=64)",
        "unchunked p95 gap": f"{p95['unchunked'] * 1e3:.2f} ms",
        "chunked p95 gap": f"{p95['chunked'] * 1e3:.2f} ms",
        "improvement": f"{ratio:.1f}x",
    }])
    return ("## TTFT interference: p95 in-flight inter-token gap during "
            f"long-prompt admission\n\n{md}")


def _prefix_ttft_section(cfg, params, csv_rows: List[str]) -> str:
    """Shared-prefix TTFT, cold vs warm: the first request with a given
    432-token system prompt pays the full chunked prefill; later sharers
    reuse its resident pool blocks and prefill only the 16-token suffix
    tail.  Gated: best-of warm TTFT must improve >= 2x over best-of cold
    (expected ~7x from the chunk-step count alone).

    One engine serves every round (compiles amortize like the
    interference scenario above); best-of-4 on each side suppresses
    scheduler noise and keeps one-off compiles (the warm path's
    suffix-width chunk) out of the gated numbers."""
    prefix_len, suffix_len, max_len = 432, 16, 512
    eng = ServingEngine(cfg, params, max_batch=2, max_len=max_len,
                        prompt_bucket=64, cache_layout="paged",
                        kv_block_size=BLOCK_SIZE,
                        # pool big enough to keep all 5 prefixes resident
                        # (no eviction between the cold and warm rounds)
                        kv_num_blocks=1 + 8 * (max_len // BLOCK_SIZE),
                        prefill_chunk=64, prefix_cache=True)
    rng = np.random.default_rng(0)
    prefixes = [rng.integers(0, cfg.vocab_size, prefix_len).astype(np.int32)
                for _ in range(5)]

    def serve_one(pid: int) -> float:
        prompt = np.concatenate([
            prefixes[pid],
            rng.integers(0, cfg.vocab_size, suffix_len).astype(np.int32)])
        eng.submit(prompt, SamplingParams(max_new_tokens=2))
        req = eng.queue[-1]
        eng.run()
        return req.ttft_s

    serve_one(0)  # warm-up: compiles the 64-wide chunk + decode shapes
    serve_one(0)  # warm-up: compiles the warm path's 16-wide suffix chunk
    cold = [serve_one(pid) for pid in (1, 2, 3, 4)]
    warm = [serve_one(pid) for pid in (1, 2, 3, 4)]
    assert eng.prefix_hits >= 5, f"warm rounds missed: {eng.prefix_hits} hits"
    skipped = eng.prefill_tokens_skipped // eng.prefix_hits
    ratio = min(cold) / max(min(warm), 1e-9)
    assert ratio >= 2.0, (
        f"prefix-cache warm TTFT regression: cold {min(cold)*1e3:.2f}ms vs "
        f"warm {min(warm)*1e3:.2f}ms ({ratio:.2f}x, expected >= 2x)")
    csv_rows.append(
        f"serving_prefix_warm_ttft,{min(warm) * 1e6:.1f},x{ratio:.2f}_vs_cold")
    md = report.to_markdown([{
        "scenario": f"{prefix_len}-token shared prefix + {suffix_len}-token "
                    f"suffix (chunk=64, block={BLOCK_SIZE})",
        "cold TTFT": f"{min(cold) * 1e3:.2f} ms",
        "warm TTFT": f"{min(warm) * 1e3:.2f} ms",
        "speedup": f"{ratio:.1f}x",
        "prefill tokens skipped/hit": skipped,
    }])
    return ("## Prefix-cache TTFT: cold vs warm shared-prefix workload\n\n"
            f"{md}")


def _overcommit_section(cfg, params, csv_rows: List[str]) -> str:
    """Pool overcommit row: a bursty trace against a pool capped at ~50%
    of the worst case, with preemption + recompute, vs the same trace on
    a full pool.  Gated: every request completes, preemptions actually
    happened, greedy streams stay identical, and goodput (tokens/sec of
    the drain) is within 2x of the uncontended run.

    Each engine serves the trace twice — the first pass warms the jit
    caches (recompute re-admissions compile per distinct chunk width),
    the second is timed.  Greedy sampling keeps the second pass's streams
    independent of the uids it draws."""
    max_batch, max_len, plen, max_new = 4, 128, 48, 32
    worst = cache_lib.default_num_blocks(max_batch, max_len, BLOCK_SIZE)
    half = worst // 2 + 1  # 17 of 33: ~50%
    arrivals = bursty_trace(cfg.vocab_size, bursts=2, burst_size=4,
                            prompt_len=plen, max_new=max_new)
    prompts = [a.prompt for a in arrivals]

    def serve(num_blocks):
        eng = ServingEngine(cfg, params, max_batch=max_batch,
                            max_len=max_len, prompt_bucket=16,
                            cache_layout="paged", kv_block_size=BLOCK_SIZE,
                            kv_num_blocks=num_blocks, prefill_chunk=16,
                            preemption="recompute")
        results = []
        for _ in range(2):  # warm pass, then the timed pass
            start = len(eng.finished)
            # per-pass counter deltas: the reported (and gated) numbers
            # must describe the timed pass, not the warm-up too
            pre0, rec0 = eng.preemptions, eng.recompute_tokens
            eng._occ_samples.clear()
            for p in prompts:
                eng.submit(p, SamplingParams(max_new_tokens=max_new))
            t0 = time.perf_counter()
            eng.run()
            dt = time.perf_counter() - t0
            done = eng.finished[start:]
            results.append((
                [list(r.output_tokens) for r in
                 sorted(done, key=lambda r: r.uid)],
                sum(len(r.output_tokens) for r in done) / dt,
                eng.preemptions - pre0, eng.recompute_tokens - rec0))
        streams, tps, npre, nrec = results[-1]
        assert len(streams) == len(prompts), (
            f"overcommit run lost requests: {len(streams)}/{len(prompts)}")
        return eng, streams, tps, npre, nrec

    full_eng, full_streams, full_tps, full_pre, _ = serve(worst)
    over_eng, over_streams, over_tps, over_pre, over_rec = serve(half)
    assert full_pre == 0, "full pool should never preempt"
    assert over_pre > 0, (
        "half-sized pool never preempted — the overcommit row is vacuous")
    assert over_streams == full_streams, (
        "preemption/recompute changed greedy token streams")
    ratio = full_tps / max(over_tps, 1e-9)
    assert ratio <= 2.0, (
        f"overcommit goodput regression: {over_tps:.1f} tok/s at "
        f"{half}/{worst} blocks vs {full_tps:.1f} uncontended "
        f"({ratio:.2f}x, gated <= 2x)")
    occ_p95 = _percentile(over_eng._occ_samples, 95)  # timed pass only
    csv_rows.append(
        f"serving_overcommit_goodput,{1e6 / over_tps:.1f},"
        f"x{over_tps / full_tps:.2f}_vs_full_pool")
    md = report.to_markdown([{
        "scenario": f"2 waves x 4 reqs ({plen}+{max_new} tokens), "
                    f"pool {half}/{worst} blocks",
        "uncontended tok/s": f"{full_tps:.1f}",
        "overcommit tok/s": f"{over_tps:.1f}",
        "goodput": f"{over_tps / full_tps:.2f}x (gated >= 0.5x)",
        "preemptions": over_pre,
        "recompute tokens": over_rec,
        "occupancy p95": f"{occ_p95:.2f}",
    }])
    return ("## Pool overcommit: bursty trace at ~50% of worst-case "
            f"blocks, preemption + recompute\n\n{md}")


def _speculative_section(cfg, params, csv_rows: List[str]) -> str:
    """Speculative decoding row: prompt-lookup drafting on the
    lookup-friendly trace (tiled-motif prompts whose greedy continuation
    keeps cycling the motif) vs the same engine with speculation off.
    Gated: greedy streams byte-identical, tokens/dispatch > 1 (verifies
    actually emit multi-token), and decode tokens/sec >= 1.5x the
    non-speculative run.

    Batch 1 on purpose: speculation is a latency technique — at high
    batch the dispatch already amortizes over the slots and the verify
    window's extra positions eat the win (especially on CPU, where the
    k+1-wide verify pays k+1 decode-equivalents of compute).  Each engine
    serves the trace twice — the first pass warms the jit caches, the
    second is timed; greedy sampling keeps both passes' streams equal."""
    max_new, max_len, spec_k = 80, 160, 6
    arrivals = lookup_friendly_trace(cfg.vocab_size, num_requests=4,
                                     motif_len=8, repeats=4, max_new=max_new)
    prompts = [a.prompt for a in arrivals]

    def serve(speculative):
        eng = ServingEngine(cfg, params, max_batch=1, max_len=max_len,
                            prompt_bucket=16, prefill_chunk=16,
                            speculative=speculative, spec_tokens=spec_k)
        results = []
        for _ in range(2):  # warm pass, then the timed pass
            start = len(eng.finished)
            for p in prompts:
                eng.submit(p, SamplingParams(max_new_tokens=max_new))
            t0 = time.perf_counter()
            eng.run()
            dt = time.perf_counter() - t0
            done = sorted(eng.finished[start:], key=lambda r: r.uid)
            results.append((
                [list(r.output_tokens) for r in done],
                sum(len(r.output_tokens) for r in done) / dt))
        streams, tps = results[-1]
        assert len(streams) == len(prompts)
        return eng, streams, tps

    base_eng, base_streams, base_tps = serve("off")
    spec_eng, spec_streams, spec_tps = serve("lookup")
    assert spec_streams == base_streams, (
        "speculative decoding changed greedy token streams")
    s = spec_eng.latency_summary()
    assert s["tokens_per_dispatch"] > 1.0, (
        f"verify dispatches never emitted multi-token "
        f"(tokens/dispatch {s['tokens_per_dispatch']:.2f})")
    ratio = spec_tps / max(base_tps, 1e-9)
    assert ratio >= 1.5, (
        f"speculative decode too slow: {spec_tps:.1f} tok/s vs plain "
        f"{base_tps:.1f} ({ratio:.2f}x, gated >= 1.5x)")
    csv_rows.append(
        f"serving_speculative,{1e6 / spec_tps:.1f},"
        f"x{ratio:.2f}_vs_plain_decode")
    md = report.to_markdown([{
        "scenario": f"4 reqs, 8-token motif x4 prompts, max_new={max_new}, "
                    f"k={spec_k}, batch 1",
        "plain tok/s": f"{base_tps:.1f}",
        "speculative tok/s": f"{spec_tps:.1f}",
        "speedup": f"{ratio:.2f}x (gated >= 1.5x)",
        "accept rate": f"{s['spec_accept_rate']:.2f}",
        "tokens/dispatch": f"{s['tokens_per_dispatch']:.1f}",
        "drafted": s["drafted_tokens"],
        "accepted": s["accepted_tokens"],
    }])
    return ("## Speculative decoding: prompt-lookup drafts, one batched "
            f"verify dispatch\n\n{md}")


def _mixed_batch_section(cfg, params, csv_rows: List[str]) -> str:
    """Mixed prefill/decode batch row: engine steps/sec and p95 TPOT under
    sustained prompt admission, unified single-dispatch step vs the
    per-chunk dispatch path.  Greedy streams must match, and the unified
    step must clear >= 1.3x steps/sec — the win is pure dispatch economics
    (>= 2 launches per step collapse into one fused launch while cursors
    are in flight).

    Each engine serves the trace twice — the first pass warms the jit
    caches (the unified path compiles one packed-frontier executable, the
    legacy path one per chunk width), the second is timed.

    Shape: a multi-quantum budget (budget = 8 x chunk) makes the legacy
    path pay ~9 launches per step while the unified engine folds the same
    frontier into a single packed dispatch."""
    max_batch, max_len, plen, max_new = 2, 128, 100, 4
    chunk, budget = 4, 32
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, plen).astype(np.int32)
               for _ in range(12)]

    def serve(unified):
        eng = ServingEngine(cfg, params, max_batch=max_batch,
                            max_len=max_len, prompt_bucket=16,
                            prefill_chunk=chunk, prefill_budget=budget,
                            unified_step=unified)
        results = []
        for _ in range(2):  # warm pass, then the timed pass
            start = len(eng.finished)
            steps0, disp0 = eng._steps_done, eng._dispatches
            for p in prompts:
                eng.submit(p, SamplingParams(max_new_tokens=max_new))
            t0 = time.perf_counter()
            eng.run()
            dt = time.perf_counter() - t0
            done = sorted(eng.finished[start:], key=lambda r: r.uid)
            nsteps = eng._steps_done - steps0
            results.append((
                [list(r.output_tokens) for r in done],
                nsteps / dt,
                _percentile([r.tpot_s for r in done], 95),
                (eng._dispatches - disp0) / max(nsteps, 1)))
        assert len(results[-1][0]) == len(prompts)
        return results[-1]

    uni_streams, uni_sps, uni_tpot, uni_dps = serve(True)
    leg_streams, leg_sps, leg_tpot, leg_dps = serve(False)
    assert uni_streams == leg_streams, (
        "unified step changed greedy token streams")
    ratio = uni_sps / max(leg_sps, 1e-9)
    assert ratio >= 1.3, (
        f"unified mixed step too slow: {uni_sps:.1f} steps/s vs per-chunk "
        f"{leg_sps:.1f} ({ratio:.2f}x, gated >= 1.3x)")
    csv_rows.append(
        f"serving_unified_step,{1e6 / uni_sps:.1f},"
        f"x{ratio:.2f}_vs_per_chunk")
    md = report.to_markdown([{
        "scenario": f"12 reqs, {plen}-token prompts (chunk={chunk}, "
                    f"budget={budget}), max_new={max_new}",
        "per-chunk steps/s": f"{leg_sps:.1f}",
        "unified steps/s": f"{uni_sps:.1f}",
        "speedup": f"{ratio:.2f}x (gated >= 1.3x)",
        "per-chunk p95 TPOT": f"{leg_tpot * 1e3:.2f} ms",
        "unified p95 TPOT": f"{uni_tpot * 1e3:.2f} ms",
        "dispatches/step": f"{uni_dps:.2f} vs {leg_dps:.2f}",
    }])
    return ("## Unified mixed prefill/decode step: one dispatch per engine "
            f"step vs per-chunk dispatches\n\n{md}")


def _sharded_section(cfg, params, axes, csv_rows: List[str]) -> str:
    """Tensor-parallel row: the same greedy paged workload served at tp=2
    (heads/FFN sharded over a ``(tp,)`` mesh) vs the single-device engine.
    Gated: streams byte-identical (sharding moves the math, never the
    tokens) and tp=2 steps/sec within 2x of tp=1 — on a forced CPU host
    the "devices" share the same silicon, so sharding only pays dispatch
    overhead; the gate catches that overhead exploding.

    Skips gracefully on a single-device host: the bench-smoke leg sets
    ``XLA_FLAGS=--xla_force_host_platform_device_count=4``."""
    from repro.launch.mesh import make_tp_mesh

    title = "## Tensor-parallel serving: tp=2 vs tp=1 (forced host)"
    if len(jax.devices()) < 2:
        return (f"{title}\n\n(skipped: single-device host — set "
                "XLA_FLAGS=--xla_force_host_platform_device_count=4)")
    max_new, plen = 24, 48
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, plen).astype(np.int32)
               for _ in range(8)]

    def serve(tp):
        # batch 4 on purpose: the forced-host "devices" share one CPU, so
        # sharding buys no compute — it costs a roughly fixed per-step
        # multi-device dispatch overhead, which a heavier step amortizes
        mesh = make_tp_mesh(tp) if tp > 1 else None
        eng = ServingEngine(cfg, params, max_batch=4, max_len=MAX_LEN,
                            prompt_bucket=16, prefill_chunk=8,
                            cache_layout="paged", kv_block_size=BLOCK_SIZE,
                            mesh=mesh,
                            param_axes=axes if mesh is not None else None)
        results = []
        for _ in range(3):  # warm pass, then best-of-2 timed passes
            start = len(eng.finished)
            steps0 = eng._steps_done
            for p in prompts:
                eng.submit(p, SamplingParams(max_new_tokens=max_new))
            t0 = time.perf_counter()
            eng.run()
            dt = time.perf_counter() - t0
            done = sorted(eng.finished[start:], key=lambda r: r.uid)
            results.append(([list(r.output_tokens) for r in done],
                            (eng._steps_done - steps0) / dt))
        streams = results[-1][0]
        sps = max(r[1] for r in results[1:])
        assert len(streams) == len(prompts)
        return eng, streams, sps

    _, base_streams, base_sps = serve(1)
    tp_eng, tp_streams, tp_sps = serve(2)
    assert tp_streams == base_streams, (
        "tp=2 sharding changed greedy token streams")
    ratio = base_sps / max(tp_sps, 1e-9)
    assert ratio <= 2.0, (
        f"sharded engine too slow: {tp_sps:.1f} steps/s at tp=2 vs "
        f"{base_sps:.1f} at tp=1 ({ratio:.2f}x slowdown, gated <= 2x)")
    per = tp_eng.kv_bytes_by_device(peak=True)
    assert sum(per) == tp_eng.kv_bytes_in_use(peak=True)
    csv_rows.append(
        f"serving_sharded_tp2,{1e6 / tp_sps:.1f},"
        f"x{tp_sps / base_sps:.2f}_vs_tp1")
    md = report.to_markdown([{
        "scenario": f"8 reqs, {plen}+{max_new} tokens, batch 4, paged "
                    f"(block={BLOCK_SIZE}), chunk=8",
        "tp=1 steps/s": f"{base_sps:.1f}",
        "tp=2 steps/s": f"{tp_sps:.1f}",
        "slowdown": f"{ratio:.2f}x (gated <= 2x)",
        "streams": "byte-identical",
        "KV bytes by device": " / ".join(str(b) for b in per),
    }])
    return f"{title}\n\n{md}"


def _server_section(cfg, params, csv_rows: List[str]) -> str:
    """Client-vs-engine steady state: drive the engine through the
    OpenAI-compatible HTTP front-end with the closed-loop generator and
    compare the latencies the *client* observed against the engine's own
    ledger for the same requests.  Gates: the energy ledger must tile
    exactly (sum of per-request ``joules_between`` windows == run total)
    and the client-minus-engine TTFT/TPOT deltas must stay within the
    serving overhead budget — if HTTP + queueing ever costs more than
    250 ms of TTFT on an idle box, the front-end has rotted."""
    try:
        import aiohttp  # noqa: F401
    except ImportError:
        return ("## Serving over HTTP: client vs engine steady state\n\n"
                "(skipped: aiohttp not installed)")
    import math

    from repro.core.energy import PowerMonitor, SyntheticReader
    from repro.serving.loadgen import LoadSpec, prewarm_engine, run_load
    from repro.serving.server import start_http_server

    eng = ServingEngine(cfg, params, max_batch=2, max_len=MAX_LEN,
                        prefill_chunk=16)
    mon = PowerMonitor(
        SyntheticReader(lambda t: 40.0 + 10.0 * math.sin(t * 7.0)),
        interval_s=0.05)
    eng.attach_monitor(mon)
    prewarm_engine(eng, prompt_len=12, concurrency=2,
                   vocab_size=cfg.vocab_size)
    handle = start_http_server(eng, model_name=cfg.name)
    try:
        spec = LoadSpec(mode="closed", concurrency=2, warmup_s=1.0,
                        duration_s=2.5, prompt_len=12, max_new=8,
                        vocab_size=cfg.vocab_size)
        res = run_load(handle.url, spec, monitor=mon)
    finally:
        handle.close()
    s = res.summary
    assert s["steady_requests"] >= 2, (
        f"steady-state window saw only {s['steady_requests']} requests")
    assert abs(s["joules_attributed"] - s["joules_total"]) <= (
        1e-9 * max(s["joules_total"], 1.0)), (
        f"energy ledger drift: {s['joules_attributed']!r} J attributed vs "
        f"{s['joules_total']!r} J total")
    assert -1.0 <= s["ttft_client_minus_engine_ms"] <= 250.0, (
        f"client-vs-engine TTFT delta {s['ttft_client_minus_engine_ms']:.1f}"
        f" ms out of bounds")
    assert abs(s["tpot_client_minus_engine_ms"]) <= 50.0, (
        f"client-vs-engine TPOT delta {s['tpot_client_minus_engine_ms']:.2f}"
        f" ms out of bounds")
    rows = [{
        "requests": int(s["steady_requests"]),
        "req/s": round(s["achieved_qps"], 1),
        "client TTFT(ms)": round(s["client_ttft_ms"], 1),
        "TTFT delta(ms)": round(s["ttft_client_minus_engine_ms"], 1),
        "client TPOT(ms)": round(s["client_tpot_ms"], 2),
        "TPOT delta(ms)": round(s["tpot_client_minus_engine_ms"], 2),
        "J/req": round(s["joules_per_request"], 2),
        "sample Hz": round(s["power_samples_per_sec"], 1),
    }]
    csv_rows.append(
        f"serving_http_ttft_delta,{s['ttft_client_minus_engine_ms']:.1f},"
        f"tpot_delta={s['tpot_client_minus_engine_ms']:.2f}ms")
    return ("## Serving over HTTP: client vs engine steady state "
            "(closed loop, energy ledger exact)\n\n"
            + report.to_markdown(rows))


def run(csv_rows: List[str]) -> str:
    cfg = get_config(ARCH, smoke=True)
    params, _ = model_lib.init(cfg, jax.random.PRNGKey(0))
    params_s = SamplingParams(temperature=0.8, top_k=20)
    rows = []
    for B in BATCHES:
        cache = model_lib.init_cache(cfg, B, MAX_LEN, jnp.dtype(cfg.dtype))
        # compile once per batch size, outside the timed regions
        decode = jax.jit(lambda p, tok, pos, c:
                         model_lib.decode_step(cfg, p, tok, pos, c))
        step_fn = make_decode_sample_step(cfg, MAX_LEN)
        fused = jax.jit(step_fn)
        cpu = jax.default_backend() == "cpu"
        _per_slot_reference_steps(decode, params, cache, B, WARMUP, params_s)
        ref_s, _ = _per_slot_reference_steps(
            decode, params, cache, B, STEPS, params_s)
        ref_sps = STEPS / ref_s
        fused_sps = _time_fused(fused, cfg, params, B, params_s)
        if cpu:
            # maybe_donate is a plain jit on CPU — timing it again would
            # compile and measure an identical executable
            donated_sps = fused_sps
        else:
            donated = maybe_donate(step_fn, (1, 2))
            donated_sps = _time_fused(donated, cfg, params, B, params_s)
        paged_sps = _time_fused(fused, cfg, params, B, params_s,
                                layout="paged")
        # regression gates.  On CPU the paged path pays an XLA gather the
        # TPU kernel avoids via scalar prefetch, so CPU only guards against
        # catastrophic rot; accelerators get the real bounds (donation must
        # not drop throughput, paged stays within ~10% of fused).
        don_floor, paged_floor = (0.4, 0.4) if cpu else (0.75, 0.9)
        assert donated_sps >= don_floor * fused_sps, (
            f"donation regression at B={B}: {donated_sps:.1f} vs "
            f"{fused_sps:.1f} steps/s")
        assert paged_sps >= paged_floor * fused_sps, (
            f"paged decode regression at B={B}: {paged_sps:.1f} vs "
            f"{fused_sps:.1f} steps/s")
        rows.append({
            "batch": B,
            "per-slot steps/s": round(ref_sps, 1),
            "fused steps/s": round(fused_sps, 1),
            "donated steps/s": round(donated_sps, 1),
            "paged steps/s": round(paged_sps, 1),
            "speedup": round(fused_sps / ref_sps, 2),
            "paged/fused": round(paged_sps / fused_sps, 2),
        })
        csv_rows.append(
            f"serving_fused_b{B},{1e6 / fused_sps:.1f},"
            f"x{fused_sps / ref_sps:.2f}_vs_per_slot")
        csv_rows.append(
            f"serving_paged_b{B},{1e6 / paged_sps:.1f},"
            f"x{paged_sps / fused_sps:.2f}_vs_fused")
    md = report.to_markdown(rows)
    section = (f"## Serving decode loop: per-slot reference vs fused step "
               f"(contiguous / donated / paged)\n\n{md}")
    return (section
            + "\n\n" + _engine_kv_section(cfg, params, csv_rows)
            + "\n\n" + _mixed_batch_section(cfg, params, csv_rows)
            + "\n\n" + _interference_section(cfg, params, csv_rows)
            + "\n\n" + _prefix_ttft_section(cfg, params, csv_rows)
            + "\n\n" + _overcommit_section(cfg, params, csv_rows)
            + "\n\n" + _server_section(cfg, params, csv_rows))
