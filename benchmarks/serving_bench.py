"""Serving decode-loop benchmark: fused device-resident step (contiguous,
donated, and paged KV layouts) vs the legacy per-slot host loop, plus an
engine-level KV-memory comparison under a short-heavy workload.

The legacy path (the seed engine's ``_decode_once``) ran one jitted decode,
then for every slot dispatched a separate ``sample`` call and synced
``int(t[0])`` to the host — O(batch) device round-trips per step.  The
fused path (``serving.step.make_decode_sample_step``) samples all slots,
advances positions/budgets and detects finishes inside one jitted call,
then syncs a single packed (3, B) array.  Decode steps/sec should improve
measurably from ``max_batch >= 4`` on CPU.

Two regression guards ride along:

* **Donation** (``maybe_donate``): donating the cache/state buffers into
  the fused step must not cost throughput — asserted at >= 0.75x the
  non-donated fused rate (generous bound; donation is a no-op on CPU).
* **Paged KV**: the block-pool layout must stay within striking distance
  of the contiguous fused path (reported as a ratio), while the engine
  section shows the point of paging — peak KV bytes actually allocated for
  a short-heavy mixed-length workload vs the contiguous worst case.
"""

from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import report
from repro.models import model as model_lib
from repro.serving.engine import ServingEngine
from repro.serving.sampling import SamplingParams, sample
from repro.serving.step import (init_slot_state, make_decode_sample_step,
                                maybe_donate)

ARCH = "qwen1.5-0.5b"
BATCHES = (1, 4, 8)
MAX_LEN = 128
BLOCK_SIZE = 16
STEPS = 30
WARMUP = 3


def _per_slot_reference_steps(decode, params, cache, B, n_steps, params_s):
    """The seed engine's decode loop: jitted decode + per-slot host sampling."""
    next_tokens = np.zeros((B, 1), np.int32)
    positions = np.full(B, 16, np.int64)
    key = jax.random.PRNGKey(0)
    t0 = time.perf_counter()
    for _ in range(n_steps):
        tok = jnp.asarray(next_tokens)
        pos = jnp.asarray(positions, jnp.int32)
        logits, cache = decode(params, tok, pos, cache)
        key, k = jax.random.split(key)
        for slot in range(B):
            t = sample(logits[slot:slot + 1], params_s,
                       jax.random.fold_in(k, slot))
            next_tokens[slot, 0] = int(t[0])      # per-slot host sync
            positions[slot] += 1
    jax.block_until_ready(logits)
    return time.perf_counter() - t0, cache


def _make_state(B, params_s, tables=None):
    state = init_slot_state(B, max_blocks=0 if tables is None
                            else tables.shape[1])
    state["active"] = jnp.ones((B,), jnp.bool_)
    state["positions"] = jnp.full((B,), 16, jnp.int32)
    state["remaining"] = jnp.full((B,), 10 ** 6, jnp.int32)
    state["temperature"] = jnp.full((B,), params_s.temperature, jnp.float32)
    state["top_k"] = jnp.full((B,), params_s.top_k, jnp.int32)
    if tables is not None:
        state["block_tables"] = tables
    return state


def _fused_steps(step, params, cache, B, n_steps, params_s, tables=None):
    state = _make_state(B, params_s, tables)
    t0 = time.perf_counter()
    for _ in range(n_steps):
        state, cache, out = step(params, state, cache)
        np.asarray(out)                           # the single host sync
    return time.perf_counter() - t0, cache


def _time_fused(step, cfg, params, B, params_s, *, layout="contiguous",
                repeats=3):
    """Warmup + best-of-``repeats`` timed runs (suppresses scheduler noise),
    each on a fresh cache (donation-safe)."""
    mk = lambda: model_lib.init_cache(cfg, B, MAX_LEN, jnp.dtype(cfg.dtype),
                                      layout=layout, block_size=BLOCK_SIZE)
    tables = None
    if layout == "paged":
        nb = MAX_LEN // BLOCK_SIZE
        tables = jnp.asarray(  # slot s owns blocks [1 + s*nb, 1 + (s+1)*nb)
            1 + np.arange(B * nb, dtype=np.int32).reshape(B, nb))
    _fused_steps(step, params, mk(), B, WARMUP, params_s, tables)
    best = min(_fused_steps(step, params, mk(), B, STEPS, params_s, tables)[0]
               for _ in range(repeats))
    return STEPS / best


def _engine_kv_section(cfg, params, csv_rows: List[str]) -> str:
    """Short-heavy mixed-length workload: paged peak KV bytes vs the
    contiguous worst case (the 2x-minimum saving the paging PR targets)."""
    rng = np.random.default_rng(0)
    plens = [int(n) for n in
             np.clip(rng.lognormal(np.log(20.0), 0.6, 12), 4, 192)]
    engines = {}
    for layout in ("contiguous", "paged"):
        eng = ServingEngine(cfg, params, max_batch=4, max_len=256,
                            prompt_bucket=16, cache_layout=layout,
                            kv_block_size=BLOCK_SIZE)
        for p in plens:
            eng.submit(rng.integers(0, cfg.vocab_size, p),
                       SamplingParams(max_new_tokens=8))
        eng.run()
        engines[layout] = eng
    worst = engines["contiguous"].kv_bytes_worst_case
    paged = engines["paged"].kv_bytes_in_use(peak=True)
    saving = worst / max(paged, 1)
    assert saving >= 2.0, (
        f"paged KV allocated {paged}B vs contiguous worst case {worst}B — "
        f"expected >= 2x saving for a short-heavy workload, got {saving:.2f}x")
    csv_rows.append(f"serving_paged_kv_bytes,{paged},saving={saving:.2f}x")
    md = report.to_markdown([{
        "workload": "12 reqs, lognormal prompts (mean~20), max_new=8",
        "contiguous worst case": f"{worst / 1e6:.2f} MB",
        "paged peak allocated": f"{paged / 1e6:.2f} MB",
        "saving": f"{saving:.1f}x",
    }])
    return ("## Engine KV memory: paged blocks-in-use vs contiguous "
            f"worst case\n\n{md}")


def run(csv_rows: List[str]) -> str:
    cfg = get_config(ARCH, smoke=True)
    params, _ = model_lib.init(cfg, jax.random.PRNGKey(0))
    params_s = SamplingParams(temperature=0.8, top_k=20)
    rows = []
    for B in BATCHES:
        cache = model_lib.init_cache(cfg, B, MAX_LEN, jnp.dtype(cfg.dtype))
        # compile once per batch size, outside the timed regions
        decode = jax.jit(lambda p, tok, pos, c:
                         model_lib.decode_step(cfg, p, tok, pos, c))
        step_fn = make_decode_sample_step(cfg, MAX_LEN)
        fused = jax.jit(step_fn)
        cpu = jax.default_backend() == "cpu"
        _per_slot_reference_steps(decode, params, cache, B, WARMUP, params_s)
        ref_s, _ = _per_slot_reference_steps(
            decode, params, cache, B, STEPS, params_s)
        ref_sps = STEPS / ref_s
        fused_sps = _time_fused(fused, cfg, params, B, params_s)
        if cpu:
            # maybe_donate is a plain jit on CPU — timing it again would
            # compile and measure an identical executable
            donated_sps = fused_sps
        else:
            donated = maybe_donate(step_fn, (1, 2))
            donated_sps = _time_fused(donated, cfg, params, B, params_s)
        paged_sps = _time_fused(fused, cfg, params, B, params_s,
                                layout="paged")
        # regression gates.  On CPU the paged path pays an XLA gather the
        # TPU kernel avoids via scalar prefetch, so CPU only guards against
        # catastrophic rot; accelerators get the real bounds (donation must
        # not drop throughput, paged stays within ~10% of fused).
        don_floor, paged_floor = (0.4, 0.4) if cpu else (0.75, 0.9)
        assert donated_sps >= don_floor * fused_sps, (
            f"donation regression at B={B}: {donated_sps:.1f} vs "
            f"{fused_sps:.1f} steps/s")
        assert paged_sps >= paged_floor * fused_sps, (
            f"paged decode regression at B={B}: {paged_sps:.1f} vs "
            f"{fused_sps:.1f} steps/s")
        rows.append({
            "batch": B,
            "per-slot steps/s": round(ref_sps, 1),
            "fused steps/s": round(fused_sps, 1),
            "donated steps/s": round(donated_sps, 1),
            "paged steps/s": round(paged_sps, 1),
            "speedup": round(fused_sps / ref_sps, 2),
            "paged/fused": round(paged_sps / fused_sps, 2),
        })
        csv_rows.append(
            f"serving_fused_b{B},{1e6 / fused_sps:.1f},"
            f"x{fused_sps / ref_sps:.2f}_vs_per_slot")
        csv_rows.append(
            f"serving_paged_b{B},{1e6 / paged_sps:.1f},"
            f"x{paged_sps / fused_sps:.2f}_vs_fused")
    md = report.to_markdown(rows)
    section = (f"## Serving decode loop: per-slot reference vs fused step "
               f"(contiguous / donated / paged)\n\n{md}")
    return section + "\n\n" + _engine_kv_section(cfg, params, csv_rows)
