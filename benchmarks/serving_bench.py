"""Serving decode-loop benchmark: fused device-resident step vs the legacy
per-slot host loop, across batch sizes.

The legacy path (the seed engine's ``_decode_once``) ran one jitted decode,
then for every slot dispatched a separate ``sample`` call and synced
``int(t[0])`` to the host — O(batch) device round-trips per step.  The
fused path (``serving.step.make_decode_sample_step``) samples all slots,
advances positions/budgets and detects finishes inside one jitted call,
then syncs a single packed (3, B) array.  Decode steps/sec should improve
measurably from ``max_batch >= 4`` on CPU.
"""

from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import report
from repro.models import model as model_lib
from repro.serving.sampling import SamplingParams, sample
from repro.serving.step import init_slot_state, make_decode_sample_step

ARCH = "qwen1.5-0.5b"
BATCHES = (1, 4, 8)
MAX_LEN = 128
STEPS = 30
WARMUP = 3


def _per_slot_reference_steps(decode, params, cache, B, n_steps, params_s):
    """The seed engine's decode loop: jitted decode + per-slot host sampling."""
    next_tokens = np.zeros((B, 1), np.int32)
    positions = np.full(B, 16, np.int64)
    key = jax.random.PRNGKey(0)
    t0 = time.perf_counter()
    for _ in range(n_steps):
        tok = jnp.asarray(next_tokens)
        pos = jnp.asarray(positions, jnp.int32)
        logits, cache = decode(params, tok, pos, cache)
        key, k = jax.random.split(key)
        for slot in range(B):
            t = sample(logits[slot:slot + 1], params_s,
                       jax.random.fold_in(k, slot))
            next_tokens[slot, 0] = int(t[0])      # per-slot host sync
            positions[slot] += 1
    jax.block_until_ready(logits)
    return time.perf_counter() - t0, cache


def _fused_steps(step, params, cache, B, n_steps, params_s):
    state = init_slot_state(B)
    state["active"] = jnp.ones((B,), jnp.bool_)
    state["positions"] = jnp.full((B,), 16, jnp.int32)
    state["remaining"] = jnp.full((B,), 10 ** 6, jnp.int32)
    state["temperature"] = jnp.full((B,), params_s.temperature, jnp.float32)
    state["top_k"] = jnp.full((B,), params_s.top_k, jnp.int32)
    t0 = time.perf_counter()
    for _ in range(n_steps):
        state, cache, out = step(params, state, cache)
        np.asarray(out)                           # the single host sync
    return time.perf_counter() - t0, cache


def run(csv_rows: List[str]) -> str:
    cfg = get_config(ARCH, smoke=True)
    params, _ = model_lib.init(cfg, jax.random.PRNGKey(0))
    params_s = SamplingParams(temperature=0.8, top_k=20)
    rows = []
    for B in BATCHES:
        cache = model_lib.init_cache(cfg, B, MAX_LEN, jnp.dtype(cfg.dtype))
        # compile once per batch size, outside the timed regions
        decode = jax.jit(lambda p, tok, pos, c:
                         model_lib.decode_step(cfg, p, tok, pos, c))
        fused = jax.jit(make_decode_sample_step(cfg, MAX_LEN))
        _per_slot_reference_steps(decode, params, cache, B, WARMUP, params_s)
        ref_s, _ = _per_slot_reference_steps(
            decode, params, cache, B, STEPS, params_s)
        _fused_steps(fused, params, cache, B, WARMUP, params_s)
        fused_s, _ = _fused_steps(fused, params, cache, B, STEPS, params_s)
        ref_sps = STEPS / ref_s
        fused_sps = STEPS / fused_s
        rows.append({
            "batch": B,
            "per-slot steps/s": round(ref_sps, 1),
            "fused steps/s": round(fused_sps, 1),
            "speedup": round(fused_sps / ref_sps, 2),
        })
        csv_rows.append(
            f"serving_fused_b{B},{1e6 * fused_s / STEPS:.1f},"
            f"x{fused_sps / ref_sps:.2f}_vs_per_slot")
    md = report.to_markdown(rows)
    return f"## Serving decode loop: per-slot reference vs fused step\n\n{md}"
