"""Measured-mode benchmark (real wall-clock + 10 Hz power sampling) on the
CPU dev rig — the paper's §2.3/2.4 machinery exercised end-to-end against
reduced-config models.  ``derived`` reports the TTLT decomposition residual
(|TTLT - (TTFT + (G-1)·TPOT)| / TTLT), the identity the paper's A6000 rows
satisfy.
"""

from __future__ import annotations

import time
from typing import List

from repro.core import energy as energy_lib
from repro.core.profiler import Elana

MODELS = ["qwen1.5-0.5b", "tinyllama-1.1b", "recurrentgemma-2b", "xlstm-1.3b"]


def run(csv_rows: List[str]) -> str:
    lines = ["## Measured mode (CPU dev rig, reduced configs, bsize=1, L=32+8)"]
    lines.append("| model | TTFT(ms) | TPOT(ms) | TTLT(ms) | J/Tok | decomp.res |")
    lines.append("|---|---|---|---|---|---|")
    for arch in MODELS:
        e = Elana(arch, smoke=True)
        t0 = time.perf_counter()
        m = e.measure(batch=1, prompt_len=32, gen_len=8, iters=3,
                      power_reader=energy_lib.ProcStatReader())
        wall = (time.perf_counter() - t0) * 1e6
        m2 = e.measure(batch=1, prompt_len=32, gen_len=8, iters=3)
        residual = abs(m2["ttlt_ms"] - (m2["ttft_ms"] + 7 * m2["tpot_ms"])) \
            / m2["ttlt_ms"]
        lines.append(
            f"| {arch} | {m2['ttft_ms']:.1f} | {m2['tpot_ms']:.1f} "
            f"| {m2['ttlt_ms']:.1f} | {m.get('j_per_token', 0):.3f} "
            f"| {residual:.2f} |")
        csv_rows.append(f"measured_{arch},{wall:.0f},decomp_residual={residual:.3f}")
    return "\n".join(lines)


if __name__ == "__main__":
    csv: List[str] = []
    print(run(csv))
    print("\n".join(csv))
