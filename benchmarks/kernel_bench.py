"""Kernel micro-benchmarks.

On this CPU rig the Pallas kernels execute in interpret mode (correctness
only), so wall-clock numbers time the *reference* implementations under
XLA-CPU; ``derived`` reports achieved GFLOP/s, which is the number to
compare against the Pallas path on real TPU hardware.
"""

from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp

from repro.core.latency import time_callable


def _flash_case(S=1024, Hq=8, Hkv=2, D=64, B=2):
    from repro.kernels.flash_attention import ref

    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, S, Hq, D), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hkv, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, Hkv, D))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S)).astype(jnp.int32)
    fn = jax.jit(lambda: ref.attention(q, k, v, q_positions=pos,
                                       k_positions=pos, causal=True))
    flops = 4.0 * B * Hq * D * S * S / 2
    return fn, flops


def _decode_case(L=8192, Hq=8, Hkv=2, D=128, B=4):
    from repro.kernels.decode_attention import ref

    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, 1, Hq, D))
    kc = jax.random.normal(jax.random.fold_in(key, 1), (B, L, Hkv, D))
    vc = jax.random.normal(jax.random.fold_in(key, 2), (B, L, Hkv, D))
    qpos = jnp.full((B, 1), L - 1, jnp.int32)
    kpos = jnp.broadcast_to(jnp.arange(L)[None], (B, L)).astype(jnp.int32)
    fn = jax.jit(lambda: ref.decode_attention(q, kc, vc, q_positions=qpos,
                                              k_positions=kpos))
    flops = 4.0 * B * Hq * D * L
    return fn, flops


def _linrec_case(S=4096, W=2560, B=1):
    from repro.kernels.linear_recurrence import ref

    key = jax.random.PRNGKey(0)
    a = jax.nn.sigmoid(jax.random.normal(key, (B, S, W))) * 0.2 + 0.8
    b = jax.random.normal(jax.random.fold_in(key, 1), (B, S, W))
    h0 = jnp.zeros((B, W))
    fn = jax.jit(lambda: ref.linear_recurrence(a, b, h0))
    flops = 3.0 * B * S * W  # a*h+b per element (assoc-scan does ~2x more)
    return fn, flops


def _rmsnorm_case(rows=8192, d=4096):
    from repro.kernels.rmsnorm import ref

    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (rows, d))
    s = jax.random.normal(jax.random.fold_in(key, 1), (d,)) * 0.1
    fn = jax.jit(lambda: ref.rmsnorm(x, s))
    flops = 4.0 * rows * d
    return fn, flops


CASES = {
    "flash_attention_ref_1k": _flash_case,
    "decode_attention_ref_8k": _decode_case,
    "linear_recurrence_ref_4k": _linrec_case,
    "rmsnorm_ref_8kx4k": _rmsnorm_case,
}


def run(csv_rows: List[str]) -> str:
    lines = ["## Kernel reference micro-benchmarks (XLA-CPU; Pallas "
             "validated in interpret mode, timed on real TPU only)"]
    lines.append("| kernel | us/call | GFLOP/s |")
    lines.append("|---|---|---|")
    for name, case in CASES.items():
        fn, flops = case()
        stats = time_callable(fn, iters=5, warmup=2, name=name)
        gflops = flops / stats.mean_s / 1e9
        lines.append(f"| {name} | {stats.mean_s*1e6:.0f} | {gflops:.1f} |")
        csv_rows.append(f"kernel_{name},{stats.mean_s*1e6:.0f},gflops={gflops:.1f}")
    return "\n".join(lines)


if __name__ == "__main__":
    csv: List[str] = []
    print(run(csv))
    print("\n".join(csv))
