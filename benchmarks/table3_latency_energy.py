"""Paper Table 3 reproduction: latency + energy on A6000 (estimator mode).

The dev container has no A6000, so this is the analytic roofline+power model
(core/estimator.py) validated cell-by-cell against the published numbers.
The multi-GPU rows are also produced under the ``naive_pp`` mode (HF
accelerate-style sequential layer placement), which is what the paper's
summed-power numbers are consistent with (see EXPERIMENTS §Paper-validation).
"""

from __future__ import annotations

import time
from typing import List

from repro.core import report
from repro.core.profiler import Elana

PAPER_1GPU = {  # nGPU=1, bsize=1, L=512+512
    "llama3.1-8b": (94.30, 25.91, 24.84, 6.80, 12859.85, 3533.09),
    "qwen2.5-7b": (88.41, 24.29, 23.15, 6.44, 12073.26, 3343.91),
    "nemotron-h-8b": (87.72, 24.00, 24.33, 6.67, 12593.76, 3437.56),
}
COLS = ("TTFT(ms)", "J/Prom.", "TPOT(ms)", "J/Tok.", "TTLT(ms)", "J/Req.")


def run(csv_rows: List[str]) -> str:
    lines = ["## Table 3: A6000, nGPU=1, bsize=1, L=512+512 (estimator vs paper)"]
    rows = []
    for arch, exp in PAPER_1GPU.items():
        t0 = time.perf_counter()
        est = Elana(arch).estimate(hardware="a6000", batch=1,
                                   prompt_len=512, gen_len=512)
        r = est.row()
        ours = (r["TTFT_ms"], r["J_per_prompt"], r["TPOT_ms"],
                r["J_per_token"], r["TTLT_ms"], r["J_per_request"])
        rels = [abs(o - p) / p for o, p in zip(ours, exp)]
        row = {"Model": arch}
        for c, o, p in zip(COLS, ours, exp):
            row[c] = round(o, 2)
            row["p" + c] = p
        row["max_rel%"] = round(max(rels) * 100, 1)
        rows.append(row)
        dt = (time.perf_counter() - t0) * 1e6
        csv_rows.append(
            f"table3_{arch},{dt:.0f},"
            f"tpot_relerr={rels[2]:.3f};jtok_relerr={rels[3]:.3f}")
    lines.append(report.to_markdown(rows))

    lines.append("\n## Table 3 multi-GPU rows (nGPU=4, bsize=64, naive_pp mode)")
    rows = []
    for arch in PAPER_1GPU:
        est = Elana(arch).estimate(hardware="a6000", n_devices=4,
                                   mode="naive_pp", batch=64,
                                   prompt_len=512, gen_len=512)
        rows.append(est.row())
    lines.append(report.to_markdown(rows, floatfmt=".1f"))
    return "\n".join(lines)


if __name__ == "__main__":
    csv: List[str] = []
    print(run(csv))
    print("\n".join(csv))
