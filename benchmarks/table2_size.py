"""Paper Table 2 reproduction: model + cache size profiling.

Exact-match validation for Llama-3.1-8B / Qwen-2.5-7B, tolerance-checked for
the Nemotron-H hybrid stand-in, then the beyond-paper extension: the same
table over all ten assigned architectures (incl. MoE active-vs-total and
recurrent-state columns the paper's GPU tool does not distinguish).
"""

from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.configs import ASSIGNED, PAPER
from repro.core import cache as cache_prof
from repro.core import report
from repro.core.profiler import Elana

PAPER_TABLE2 = {
    "llama3.1-8b": (16.06, 0.13, 17.18, 34.36),
    "qwen2.5-7b": (15.23, 0.06, 7.52, 15.03),
    "nemotron-h-8b": (16.20, 0.05, 3.32, 6.64),
}

WORKLOADS = [(1, 1024), (128, 1024), (128, 2048)]


def run(csv_rows: List[str]) -> str:
    lines = ["## Table 2: model + KV/state cache size (paper models)"]
    rows = []
    for arch, exp in PAPER_TABLE2.items():
        t0 = time.perf_counter()
        e = Elana(arch)
        s = e.size_report()
        row = {"Model": arch, "Param(GB)": round(s.param_bytes / 1e9, 2),
               "paper": exp[0]}
        rel = abs(s.param_bytes / 1e9 - exp[0]) / exp[0]
        for (b, L), pv in zip(WORKLOADS, exp[1:]):
            rep = e.cache_report(b, L)
            row[f"kv({b},{L})"] = round(rep.kv_bytes / 1e9, 2)
            row[f"paper({b},{L})"] = pv
            rel = max(rel, abs(rep.kv_bytes / 1e9 - pv) / max(pv, 1e-9))
        rows.append(row)
        dt = (time.perf_counter() - t0) * 1e6
        csv_rows.append(f"table2_{arch},{dt:.0f},max_relerr={rel:.3f}")
    lines.append(report.to_markdown(rows))

    lines.append("\n## Paged KV: bytes allocated vs worst-case contiguous")
    lines.append(
        "\nMixed-length (short-heavy lognormal) workload at batch=128, "
        "max_len=2048, block_size=16: a contiguous cache reserves the "
        "worst case for every slot; the paged pool allocates "
        "ceil(len/16) blocks per request.")
    rng = np.random.default_rng(0)
    lengths = np.clip(
        rng.lognormal(np.log(256.0), 0.8, size=128).astype(int), 16, 2048)
    rows = []
    for arch in PAPER_TABLE2:
        e = Elana(arch)
        worst = cache_prof.analytic_kv_bytes(e.cfg, 128, 2048)
        paged = cache_prof.paged_kv_bytes(e.cfg, lengths, 16, max_len=2048)
        rows.append({
            "Model": arch,
            "contiguous(GB)": round(worst / 1e9, 2),
            "paged(GB)": round(paged / 1e9, 2),
            "saving": f"{worst / max(paged, 1):.1f}x",
        })
        csv_rows.append(
            f"table2_paged_{arch},0,saving={worst / max(paged, 1):.2f}x")
    lines.append(report.to_markdown(rows))

    lines.append("\n## Beyond paper: all assigned architectures")
    rows = []
    for arch in ASSIGNED:
        e = Elana(arch)
        s = e.size_report()
        rep = e.cache_report(128, 2048)
        rows.append({
            "Model": arch,
            "Param(GB)": round(s.param_bytes / 1e9, 2),
            "Active(GB)": round(s.active_param_bytes / 1e9, 2),
            "kv(128,2048)": round(rep.kv_bytes / 1e9, 2),
            "state(128,2048)": round(rep.state_bytes / 1e9, 2),
            "cross": round(rep.cross_bytes / 1e9, 2),
        })
    lines.append(report.to_markdown(rows))
    return "\n".join(lines)


if __name__ == "__main__":
    csv: List[str] = []
    print(run(csv))
    print("\n".join(csv))
