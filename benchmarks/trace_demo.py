"""Paper Figure 1 analogue: kernel-level timeline exported for Perfetto.

Writes chrome-trace JSONs for a decode step and a prefill of Llama-3.1-8B
on the TPU-v5e target (open at https://ui.perfetto.dev) and prints the
category breakdown.  ``derived`` = memory-bound fraction of the timeline.
"""

from __future__ import annotations

import os
import time
from typing import List

from repro.core.profiler import Elana

OUT_DIR = os.path.join(os.path.dirname(__file__), "traces")


def run(csv_rows: List[str]) -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    lines = ["## Kernel-level timeline (Perfetto chrome-trace export)"]
    e = Elana("llama3.1-8b")
    for phase, batch, seq in (("decode", 1, 2048), ("prefill", 4, 2048)):
        path = os.path.join(OUT_DIR, f"llama31_{phase}.json")
        t0 = time.perf_counter()
        s = e.trace(path, hardware="tpu-v5e", phase=phase, batch=batch,
                    seq_len=seq)
        wall = (time.perf_counter() - t0) * 1e6
        # repo-relative in the committed RESULTS.md: the JSONs are
        # local-only scratch (gitignored), not checked-in artifacts
        rel = os.path.relpath(path, os.path.dirname(OUT_DIR))
        lines.append(
            f"- `benchmarks/{rel}` (local harness output): "
            f"est total {s['total_s']*1e3:.2f} ms, "
            f"gemm {s.get('gemm_s', 0)*1e3:.2f} ms, "
            f"attn {s.get('attn_s', 0)*1e3:.2f} ms, "
            f"memory-bound frac {s['memory_bound_frac']:.2f}")
        csv_rows.append(f"trace_{phase},{wall:.0f},"
                        f"membound={s['memory_bound_frac']:.2f}")
    return "\n".join(lines)


if __name__ == "__main__":
    csv: List[str] = []
    print(run(csv))
    print("\n".join(csv))
