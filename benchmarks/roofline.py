"""Roofline table: aggregates the dry-run JSONs into EXPERIMENTS.md §Roofline.

Per (arch x shape x mesh): the three roofline terms (seconds), the dominant
bottleneck, MODEL_FLOPS / HLO_FLOPs (useful-compute ratio), memory fit, and
a what-would-move-it note derived from the dominant term.
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

from repro.core import report

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "dryrun_results")

NOTES = {
    "compute": "raise per-chip math utilization: larger per-device tiles "
               "(less model parallelism), fuse attention (Pallas), bf16 accums",
    "memory": "cut HBM traffic: fuse norms/elementwise into matmuls, remat "
              "less aggressively, keep fp32 accumulators out of HBM",
    "collective": "re-shard to cheaper collectives: reduce-scatter gradient "
                  "accumulation, fewer weight all-gathers (2D sharding), "
                  "overlap collectives with compute",
}


def load_cells(mesh: str = None) -> List[Dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json"))):
        with open(path) as f:
            cell = json.load(f)
        if mesh and cell.get("mesh") != mesh:
            continue
        cells.append(cell)
    return cells


PEAK_FLOPS = 197e12


def _fix_multipod_flops(c: Dict) -> Dict:
    """Multi-pod cells skip the unrolled lowering; global FLOPs are mesh-
    independent, so take them from the single-pod twin and recompute the
    compute term / useful ratio."""
    if c.get("mesh") != "2x16x16" or c.get("status") != "ok":
        return c
    if c["cost"].get("flops_unrolled_global"):
        return c
    twin = os.path.join(RESULTS_DIR, f"{c['arch']}__{c['shape']}__16x16.json")
    if not os.path.exists(twin):
        return c
    with open(twin) as f:
        t = json.load(f)
    if t.get("status") != "ok":
        return c
    fg = t["cost"]["flops_global"]
    c["cost"]["flops_global"] = fg
    c["roofline"]["compute_term_s"] = fg / (c["chips"] * PEAK_FLOPS)
    c["roofline"]["useful_flops_ratio"] = t["roofline"]["model_flops"] / max(fg, 1.0)
    terms = {"compute": c["roofline"]["compute_term_s"],
             "memory": c["roofline"]["memory_term_s"],
             "collective": c["roofline"]["collective_term_s"]}
    c["roofline"]["dominant"] = max(terms, key=terms.get)
    return c


def rows_for(cells: List[Dict]) -> List[Dict]:
    rows = []
    for c in cells:
        c = _fix_multipod_flops(c)
        if c.get("status") == "skipped":
            rows.append({"arch": c["arch"], "shape": c["shape"],
                         "mesh": c.get("mesh", "?"), "status": "skip",
                         "note": c["reason"][:60]})
            continue
        if c.get("status") != "ok":
            rows.append({"arch": c["arch"], "shape": c["shape"],
                         "mesh": c.get("mesh", "?"), "status": "ERROR"})
            continue
        r = c["roofline"]
        m = c["memory"]
        rows.append({
            "arch": c["arch"], "shape": c["shape"], "mesh": c["mesh"],
            "status": "ok",
            "compute_ms": round(r["compute_term_s"] * 1e3, 2),
            "memory_ms": round(r["memory_term_s"] * 1e3, 2),
            "coll_ms": round(r["collective_term_s"] * 1e3, 2),
            "bound": r["dominant"],
            "useful": round(r["useful_flops_ratio"], 2),
            "GB/dev": round(m["peak_bytes_estimate"] / 1e9, 1),
            "note": NOTES.get(r["dominant"], "")[:46],
        })
    return rows


def run(csv_rows: List[str]) -> str:
    lines = []
    for mesh in ("16x16", "2x16x16"):
        cells = load_cells(mesh)
        if not cells:
            continue
        lines.append(f"## Roofline — mesh {mesh} "
                     f"({'single pod' if mesh == '16x16' else '2 pods'})")
        rows = rows_for(cells)
        lines.append(report.to_markdown(rows))
        ok = [r for r in rows if r["status"] == "ok"]
        for r in ok:
            dom = {"compute": r["compute_ms"], "memory": r["memory_ms"],
                   "collective": r["coll_ms"]}[r["bound"]]
            csv_rows.append(
                f"roofline_{r['arch']}_{r['shape']}_{mesh},{dom*1e3:.0f},"
                f"bound={r['bound']};useful={r['useful']}")
        lines.append(f"\ncells ok: {len(ok)}, skipped: "
                     f"{sum(1 for r in rows if r['status'] == 'skip')}, "
                     f"errors: {sum(1 for r in rows if r['status'] == 'ERROR')}\n")
    return "\n".join(lines) if lines else "(no dryrun results yet)"


if __name__ == "__main__":
    csv: List[str] = []
    print(run(csv))
