"""Benchmark harness: one module per paper table/figure + the roofline
aggregation.  Prints per-benchmark ``name,us_per_call,derived`` CSV at the
end and writes the rendered tables to ``benchmarks/RESULTS.md``.

    PYTHONPATH=src python -m benchmarks.run [--only table2,roofline]
"""

from __future__ import annotations

import argparse
import os
import sys
import time
from typing import List

from benchmarks import (kernel_bench, measured_cpu, roofline, serving_bench,
                        sharded_bench, speculative_bench, table2_size,
                        table3_latency_energy, table4_jetson, trace_demo)

MODULES = {
    "table2": table2_size,            # paper Table 2
    "table3": table3_latency_energy,  # paper Table 3
    "table4": table4_jetson,          # paper Table 4
    "trace": trace_demo,              # paper Figure 1
    "measured": measured_cpu,         # §2.3/2.4 measured mode
    "kernels": kernel_bench,          # Pallas kernel reference timings
    "serving": serving_bench,         # fused vs per-slot decode loop
    "speculative": speculative_bench,  # prompt-lookup drafting vs plain decode
    "sharded": sharded_bench,         # tp=2 vs tp=1 sharding equivalence
    "roofline": roofline,             # assignment §Roofline (from dry-run JSONs)
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="comma-separated module keys")
    args = ap.parse_args(argv)
    keys = ([k.strip() for k in args.only.split(",") if k.strip()]
            if args.only else list(MODULES))
    unknown = sorted(set(keys) - set(MODULES))
    if unknown:
        ap.error(f"unknown module key(s): {', '.join(unknown)} "
                 f"(available: {', '.join(MODULES)})")

    csv_rows: List[str] = []
    sections: List[str] = []
    for key in keys:
        mod = MODULES[key]
        print(f"[bench] {key} ...", flush=True)
        t0 = time.perf_counter()
        try:
            sections.append(mod.run(csv_rows))
        except Exception as e:  # keep the harness alive; record the failure
            sections.append(f"## {key}: FAILED\n```\n{e!r}\n```")
            csv_rows.append(f"{key},0,FAILED")
        print(f"[bench] {key} done in {time.perf_counter()-t0:.1f}s", flush=True)

    out_md = os.path.join(os.path.dirname(__file__), "RESULTS.md")
    with open(out_md, "w") as f:
        f.write("\n\n".join(sections) + "\n")

    print("\n\n".join(sections))
    print("\n=== CSV (name,us_per_call,derived) ===")
    print("name,us_per_call,derived")
    for row in csv_rows:
        print(row)
    print(f"\nwrote {out_md}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
