"""ELANA core analyzer tests: units, size (paper Table 2 exact), cache,
latency semantics, energy monitor, estimator, HLO parsing, trace export."""

import json
import math
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import cache as cache_prof
from repro.core import energy as energy_lib
from repro.core import estimator as est_lib
from repro.core import hlo as hlo_lib
from repro.core import size as size_prof
from repro.core import trace as trace_lib
from repro.core import units
from repro.core.hardware import get_hardware
from repro.core.profiler import Elana


# -- units (paper §2.2: SI default, binary optional) -------------------------

def test_units_si_vs_binary():
    n = 16_060_000_000
    assert abs(units.convert(n, "GB") - 16.06) < 1e-9
    assert units.convert(n, "GiB") == pytest.approx(n / 1024**3)
    assert units.convert(1024**3, "GiB") == 1.0
    assert units.fmt_bytes(1_000_000_000, "GB") == "1.00 GB"


def test_units_auto():
    assert units.auto_unit(500) == "B"
    assert units.auto_unit(5_000_000) == "MB"
    assert units.auto_unit(5 * 1024**3, binary=True) == "GiB"


# -- model size: exact reproduction of paper Table 2 -------------------------

PAPER_TABLE2 = {
    # model: (param_GB, kv(1,1024), kv(128,1024), kv(128,2048))  [SI GB]
    "llama3.1-8b": (16.06, 0.13, 17.18, 34.36),
    "qwen2.5-7b": (15.23, 0.06, 7.52, 15.03),
}


@pytest.mark.parametrize("arch,expected", PAPER_TABLE2.items())
def test_table2_exact(arch, expected):
    e = Elana(arch)
    s = e.size_report()
    assert round(s.param_bytes / 1e9, 2) == expected[0]
    for (b, L), exp in zip([(1, 1024), (128, 1024), (128, 2048)], expected[1:]):
        rep = e.cache_report(b, L)
        assert round(rep.kv_bytes / 1e9, 2) == exp, (arch, b, L)


def test_table2_nemotron_within_tolerance():
    """Hybrid stand-in: params within 2%, KV within 5% of the paper."""
    e = Elana("nemotron-h-8b")
    s = e.size_report()
    assert abs(s.param_bytes / 1e9 - 16.20) / 16.20 < 0.02
    rep = e.cache_report(128, 2048)
    assert abs(rep.kv_bytes / 1e9 - 6.64) / 6.64 < 0.05
    assert rep.state_bytes > 0  # recurrent states are reported separately


def test_moe_active_params():
    s = size_prof.profile_size(get_config("qwen3-moe-30b-a3b"))
    assert 28e9 < s.param_count < 33e9        # "30B"
    assert 2.5e9 < s.active_param_count < 4e9  # "A3B"


def test_cache_analytic_matches_eval_shape():
    for arch in ("llama3.1-8b", "recurrentgemma-2b", "nemotron-h-8b"):
        cfg = get_config(arch)
        rep = cache_prof.profile_cache(cfg, 4, 4096)
        analytic = cache_prof.analytic_kv_bytes(cfg, 4, 4096, itemsize=2)
        assert rep.kv_bytes == analytic, arch


def test_cache_sliding_window_caps():
    cfg = get_config("recurrentgemma-2b")
    small = cache_prof.profile_cache(cfg, 1, 1024)
    big = cache_prof.profile_cache(cfg, 1, 524_288)
    # windowed KV is capped by the 2048 window: cache barely grows with L
    assert big.kv_bytes == cache_prof.analytic_kv_bytes(cfg, 1, 524_288)
    assert big.kv_bytes <= small.kv_bytes * 2 + 1
    assert big.state_bytes == small.state_bytes


# -- energy monitor -----------------------------------------------------------

def test_power_monitor_integrates_constant_power():
    reader = energy_lib.SyntheticReader(lambda t: 100.0, n_devices=2)
    with energy_lib.PowerMonitor(reader, interval_s=0.02) as mon:
        time.sleep(0.25)
    res = mon.result()
    assert res.n_devices == 2
    assert res.avg_watts == pytest.approx(200.0, rel=0.01)  # summed devices
    assert res.joules == pytest.approx(200.0 * res.duration_s, rel=0.01)


def test_power_monitor_window_average():
    # power ramps 0 -> 100 W linearly over the window: average ~50 W
    reader = energy_lib.SyntheticReader(lambda t: min(t / 0.2, 1.0) * 100.0)
    with energy_lib.PowerMonitor(reader, interval_s=0.01) as mon:
        time.sleep(0.2)
    res = mon.result()
    assert 30.0 < res.avg_watts < 70.0


def test_power_monitor_result_equals_joules_between():
    """Run-level and per-request energy share one ledger: result() is the
    same step-function integral joules_between computes, so tiling the
    window with sub-windows reproduces the total exactly."""
    reader = energy_lib.SyntheticReader(lambda t: 40.0 + 30.0 * (t % 0.05))
    with energy_lib.PowerMonitor(reader, interval_s=0.01) as mon:
        time.sleep(0.2)
    res = mon.result()
    t0, t1 = mon.window
    assert res.joules == mon.joules_between(t0, t1)
    tm = t0 + (t1 - t0) / 3.0
    assert mon.joules_between(t0, tm) + mon.joules_between(tm, t1) == (
        pytest.approx(res.joules, rel=1e-9))
    assert res.samples_per_sec > 0.0


class _FlakyReader(energy_lib.PowerReader):
    """Raises on every other read."""

    def __init__(self):
        self.calls = 0

    def read_watts(self):
        self.calls += 1
        if self.calls % 2 == 0:
            raise RuntimeError("transient sensor failure")
        return [50.0]


def test_power_monitor_counts_and_warns_on_dropped_reads():
    mon = energy_lib.PowerMonitor(_FlakyReader(), interval_s=0.01)
    with pytest.warns(RuntimeWarning, match="dropped"):
        with mon:
            time.sleep(0.15)
    res = mon.result()
    assert res.dropped_reads >= 1
    assert mon.dropped_reads == res.dropped_reads
    # the good half of the reads still integrates to a sane total
    assert res.joules == pytest.approx(50.0 * res.duration_s, rel=0.05)


class _SlowReader(energy_lib.PowerReader):
    """A read that takes longer than the idle budget (like NVML on a busy
    box) — sleep-after-read scheduling would halve the achieved rate."""

    def read_watts(self):
        time.sleep(0.03)
        return [42.0]


def test_power_monitor_absolute_deadline_rate():
    with energy_lib.PowerMonitor(_SlowReader(), interval_s=0.05) as mon:
        time.sleep(0.5)
    res = mon.result()
    # deadline scheduling: read latency eats the idle wait, not the
    # cadence.  The drifting sampler achieved ~1/(0.05+0.03) = 12.5 Hz;
    # the deadline sampler holds ~20 Hz.
    assert res.samples_per_sec >= 0.7 / 0.05
    assert res.dropped_reads == 0


def test_procstat_reader_runs():
    r = energy_lib.ProcStatReader(idle_watts=10, tdp_watts=65)
    w = r.read_watts()
    assert len(w) == 1 and 0 <= w[0] <= 65.0


# -- estimator ---------------------------------------------------------------

def test_estimator_paper_table3_decode_accuracy():
    """TPOT / J-per-token on A6000 must match the paper within 10%."""
    paper = {"llama3.1-8b": (24.84, 6.80), "qwen2.5-7b": (23.15, 6.44)}
    for arch, (tpot_ms, j_tok) in paper.items():
        est = Elana(arch).estimate(hardware="a6000", batch=1,
                                   prompt_len=512, gen_len=512)
        assert abs(est.tpot.latency_s * 1e3 - tpot_ms) / tpot_ms < 0.10, arch
        assert abs(est.tpot.joules - j_tok) / j_tok < 0.10, arch


def test_estimator_ttlt_decomposition():
    est = Elana("llama3.1-8b").estimate(hardware="a6000", batch=1,
                                        prompt_len=512, gen_len=512)
    expected = est.ttft.latency_s + 511 * est.tpot.latency_s
    assert est.ttlt.latency_s == pytest.approx(expected, rel=1e-6)


def test_estimator_monotonic_in_batch():
    e = Elana("qwen2.5-7b")
    lat1 = e.estimate(hardware="tpu-v5e", batch=1).ttft.latency_s
    lat8 = e.estimate(hardware="tpu-v5e", batch=8).ttft.latency_s
    assert lat8 > lat1


def test_estimator_naive_pp_power_model():
    """Multi-GPU naive pipeline: only one GPU busy -> watts ~ 1 busy + idle."""
    est = est_lib.estimate_workload(
        get_config("llama3.1-8b"), hardware="a6000", n_devices=4,
        mode="naive_pp", batch=1, prompt_len=512, gen_len=64)
    hw = get_hardware("a6000")
    assert est.tpot.avg_watts < 1.5 * hw.tdp_watts  # not 4 busy GPUs


# -- HLO parsing ---------------------------------------------------------------

HLO_SAMPLE = """
ENTRY %main {
  %ar = bf16[1024,512]{1,0} all-reduce(bf16[1024,512]{1,0} %x), replica_groups={}
  %ag = f32[2048]{0} all-gather(f32[128]{0} %y), dimensions={0}
  %rs.1 = bf16[64,64]{1,0} reduce-scatter(bf16[1024,64]{1,0} %z), dimensions={0}
  %cp = u32[16]{0} collective-permute(u32[16]{0} %w), source_target_pairs={{0,1}}
  %aa = (f32[32]{0}, f32[32]{0}) all-to-all(f32[32]{0} %a, f32[32]{0} %b)
  %done = f32[8]{0} all-reduce-done(f32[8]{0} %start)
}
"""


def test_collective_parsing():
    stats = hlo_lib.collective_stats(HLO_SAMPLE)
    assert stats.counts["all-reduce"] == 1
    assert stats.bytes_by_kind["all-reduce"] == 1024 * 512 * 2
    assert stats.bytes_by_kind["all-gather"] == 2048 * 4
    assert stats.bytes_by_kind["reduce-scatter"] == 64 * 64 * 2
    assert stats.bytes_by_kind["all-to-all"] == 2 * 32 * 4
    assert stats.counts["collective-permute"] == 1


def test_cost_summary_from_compiled():
    f = jax.jit(lambda x: (x @ x).sum())
    compiled = f.lower(jnp.ones((128, 128))).compile()
    s = hlo_lib.summarize_compiled(compiled)
    assert s.flops >= 2 * 128**3 * 0.9
    assert s.collectives.total_count == 0


# -- trace export ---------------------------------------------------------------

def test_trace_chrome_export(tmp_path):
    e = Elana("tinyllama-1.1b")
    path = str(tmp_path / "trace.json")
    summary = e.trace(path, hardware="tpu-v5e", phase="decode", seq_len=512)
    assert os.path.exists(path)
    data = json.load(open(path))
    assert len(data["traceEvents"]) > 22  # >= one event per layer
    assert summary["total_s"] > 0
    assert 0.9 < summary["memory_bound_frac"] <= 1.0  # bs=1 decode is mem-bound


def test_trace_prefill_compute_bound():
    ev = trace_lib.estimated_timeline(
        get_config("llama3.1-8b"), hardware="a6000", phase="prefill",
        batch=4, seq_len=2048)
    s = trace_lib.timeline_summary(ev)
    assert s["memory_bound_frac"] < 0.35  # large prefill is compute-bound
