"""Chunked-prefill scheduler equivalence suite.

The contract under test: enabling chunked prefill changes *when* prompt
work happens, never *what* tokens come out.  For chunk sizes {1, 16,
>= prompt length} x {contiguous, paged} x {greedy, sampled}, a chunked
engine must emit token streams byte-identical to the unchunked engine for
the same seed — including hybrid attn/local_attn stacks where a chunk can
exceed the sliding window.  Scheduler behavior rides along: decodes keep
flowing while another request's prompt admits chunk by chunk, and a slot
never decodes before its final chunk lands.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as model_lib
from repro.models.config import ModelConfig
from repro.serving.engine import ServingEngine
from repro.serving.sampling import SamplingParams
from repro.serving.workload import LengthDist, WorkloadSpec, poisson_trace

pytestmark = pytest.mark.chunked

CHUNKS = (1, 16, 999)  # 999 >= every bucketed prompt: degenerate single chunk


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("tinyllama-1.1b", smoke=True)
    params, _ = model_lib.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def hybrid_model():
    """Tiny stack mixing full attention with sliding-window layers."""
    cfg = ModelConfig(
        name="toy-hybrid", num_layers=4, d_model=64, num_heads=4,
        num_kv_heads=2, d_ff=128, vocab_size=256,
        block_pattern=("attn", "local_attn"), sliding_window=12,
        dtype="float32", param_dtype="float32",
    ).validate()
    params, _ = model_lib.init(cfg, jax.random.PRNGKey(1))
    return cfg, params


def _arrivals(cfg, n=6, temperature=0.0, seed=2):
    spec = WorkloadSpec(
        arrival_rate=0.0, num_requests=n,
        prompt_len=LengthDist(kind="lognormal", mean=16.0, low=2, high=48),
        output_len=LengthDist(kind="uniform", low=2, high=9),
        temperature=temperature, top_k=8, seed=seed,
    )
    return poisson_trace(spec, cfg.vocab_size)


def _streams(cfg, params, arrivals, layout, chunk, **kw):
    eng = ServingEngine(cfg, params, max_batch=2, max_len=64,
                        prompt_bucket=8, cache_layout=layout,
                        prefill_chunk=chunk, **kw)
    for a in arrivals:
        eng.submit(a.prompt, a.params)
    finished = eng.run()
    return eng, {r.uid: list(r.output_tokens) for r in finished}


@pytest.mark.parametrize("temperature", [0.0, 0.7])
@pytest.mark.parametrize("layout", ["contiguous", "paged"])
def test_chunked_matches_unchunked(small_model, layout, temperature):
    """Chunked engines (1-token, 16-token, and >=-prompt chunks) emit the
    unchunked engine's exact streams under queue pressure, both layouts,
    greedy and sampled."""
    cfg, params = small_model
    arrivals = _arrivals(cfg, temperature=temperature)
    _, base = _streams(cfg, params, arrivals, layout, 0)
    assert len(base) == len(arrivals)
    for chunk in CHUNKS:
        eng, got = _streams(cfg, params, arrivals, layout, chunk)
        assert got == base, f"chunk={chunk} diverged from unchunked"
        if layout == "paged":
            assert eng.blocks_in_use == 0  # every block returned at drain


@pytest.mark.parametrize("layout", ["contiguous", "paged"])
def test_chunked_matches_unchunked_sliding_window(hybrid_model, layout):
    """Hybrid attn/local_attn stacks: chunked == unchunked even when the
    chunk (16) exceeds the sliding window (12), the case where a ring
    evicts part of the chunk during its own append."""
    cfg, params = hybrid_model
    arrivals = _arrivals(cfg, n=5, temperature=0.7, seed=7)
    _, base = _streams(cfg, params, arrivals, layout, 0)
    for chunk in CHUNKS:
        _, got = _streams(cfg, params, arrivals, layout, chunk)
        assert got == base, f"hybrid chunk={chunk} diverged"


def test_decode_interleaves_with_chunked_admission(small_model):
    """In-flight decodes keep emitting while a long prompt admits chunk by
    chunk, and the admitting request stays silent until its final chunk."""
    cfg, params = small_model
    eng = ServingEngine(cfg, params, max_batch=2, max_len=64,
                        prompt_bucket=8, prefill_chunk=8)
    rng = np.random.default_rng(3)
    victim_uid = eng.submit(rng.integers(0, cfg.vocab_size, 8),
                            SamplingParams(max_new_tokens=40))
    eng.step()  # victim admitted (1 chunk) and decoding
    victim = eng.slots[[s is not None and s.uid == victim_uid
                        for s in eng.slots].index(True)]
    assert len(victim.output_tokens) >= 1
    long_uid = eng.submit(rng.integers(0, cfg.vocab_size, 40),
                          SamplingParams(max_new_tokens=4))
    long_req = eng.queue[-1]
    emitted_during_admission = 0
    for _ in range(5):  # 40-token bucketed prompt / 8-token chunks
        before = len(victim.output_tokens) + int(eng._ring_n[0])
        eng.step()
        eng._flush_ring(0)
        cursor_open = any(c is not None for c in eng._cursors)
        if cursor_open:
            # prefilling slot is not decode-eligible and emits nothing
            assert long_req.output_tokens == []
            assert long_req.first_token_time == 0.0
            slot = next(s for s, c in enumerate(eng._cursors) if c is not None)
            assert not bool(eng._state["active"][slot])
            emitted_during_admission += len(victim.output_tokens) - before
    # the victim decoded during the long prompt's admission window
    assert emitted_during_admission >= 3
    assert long_req.uid == long_uid and len(long_req.output_tokens) >= 1
    finished = eng.run()
    assert sorted(r.uid for r in finished) == [victim_uid, long_uid]


def test_chunk_budget_bounds_per_step_prefill_work(small_model):
    """With the default budget (= one chunk) a 32-token prompt takes
    ceil(32/8) = 4 engine steps to become decode-eligible; a larger
    budget admits it proportionally faster."""
    cfg, params = small_model
    rng = np.random.default_rng(4)
    prompt = rng.integers(0, cfg.vocab_size, 32)

    def steps_to_first_token(**kw):
        eng = ServingEngine(cfg, params, max_batch=1, max_len=64,
                            prompt_bucket=8, **kw)
        eng.submit(prompt, SamplingParams(max_new_tokens=4))
        req = eng.queue[-1]
        for n in range(1, 20):
            eng.step()
            if req.first_token_time > 0.0:
                return n
        raise AssertionError("prompt never finished prefilling")

    assert steps_to_first_token(prefill_chunk=8) == 4
    assert steps_to_first_token(prefill_chunk=8, prefill_budget=16) == 2
    assert steps_to_first_token(prefill_chunk=0) == 1  # unchunked: one stall
    # a budget below one chunk clamps up instead of stalling the cursor
    # forever (no chunk would ever fit the per-step budget)
    assert steps_to_first_token(prefill_chunk=8, prefill_budget=4) == 4


def test_chunked_pool_backpressure_and_block_reuse(small_model):
    """Chunked admission reserves pool blocks exactly like unchunked:
    a pool that fits one request forces queueing, blocks return on
    finish, and all requests complete."""
    cfg, params = small_model
    blocks_per_req = 64 // 16
    eng = ServingEngine(cfg, params, max_batch=2, max_len=64,
                        prompt_bucket=8, cache_layout="paged",
                        kv_block_size=16, kv_num_blocks=1 + blocks_per_req,
                        prefill_chunk=8)
    rng = np.random.default_rng(5)
    for _ in range(3):
        eng.submit(rng.integers(0, cfg.vocab_size, 8),
                   SamplingParams(max_new_tokens=60))
    eng.step()
    assert sum(s is not None for s in eng.slots) == 1
    assert eng.blocks_in_use == blocks_per_req
    finished = eng.run()
    assert len(finished) == 3
    assert eng.peak_blocks_in_use == blocks_per_req
    assert eng.blocks_in_use == 0
    # freed slots point their device table rows back at the garbage block
    assert int(jnp.sum(eng._state["block_tables"])) == 0


def test_serve_driver_chunked():
    from repro.launch.serve import main

    assert main(["--arch", "qwen1.5-0.5b", "--smoke", "--requests", "3",
                 "--max-new", "4", "--max-batch", "2", "--max-len", "64",
                 "--prefill-chunk", "8", "--power-reader", "none"]) == 0
