"""KV pool overcommit: preemption + recompute correctness.

The contract mirrors the other scheduler suites: overcommitting the pool
changes *when* work happens (requests are preempted, parked, and their
prefixes recomputed), never *what* comes out.  With the pool capped at
~50% of the worst case on a colliding workload, every request must still
complete and every token stream must be byte-identical to the
uncontended run — {greedy, sampled} x {chunked, unchunked}, against both
the contiguous layout (which cannot overcommit) and the full-pool paged
layout.  The scheduler invariants ride along: the head-of-line is never
preempted, shared prefix blocks are never reclaimed while referenced,
and the pool drains balanced.
"""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import cache as cache_lib
from repro.models import model as model_lib
from repro.serving.engine import ServingEngine
from repro.serving.sampling import SamplingParams
from repro.serving.workload import (bursty_trace, estimate_concurrency,
                                    shared_prefix_trace)

BS = 8          # kv block size: max_len=64 -> 8 blocks per worst-case slot
HALF_POOL = 9   # ~50% of the 2-slot worst case (17), and the legal minimum


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("tinyllama-1.1b", smoke=True)
    params, _ = model_lib.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _engine(cfg, params, **kw):
    kw.setdefault("cache_layout", "paged")
    kw.setdefault("kv_block_size", BS)
    return ServingEngine(cfg, params, max_batch=2, max_len=64,
                        prompt_bucket=8, **kw)


def _colliding_prompts(cfg, n=6, plen=24, seed=0):
    """24-token prompts reserve 4 of 8 allocatable blocks each under lazy
    reservation, so two admit concurrently and their decode growth (past
    position 32) collides on the half-sized pool."""
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, plen).astype(np.int32)
            for _ in range(n)]


def _streams(cfg, params, prompts, params_s, **kw):
    eng = _engine(cfg, params, **kw)
    for p in prompts:
        eng.submit(p, params_s)
    eng.run()
    return eng, {r.uid: list(r.output_tokens) for r in eng.finished}


# -- stream equivalence under overcommit -------------------------------------

@pytest.mark.parametrize("temperature", [0.0, 0.7])
@pytest.mark.parametrize("chunk", [0, 8])
def test_overcommit_streams_match_uncontended(small_model, chunk, temperature):
    """Half-sized pool + preemption: all requests complete with streams
    byte-identical to the contiguous AND the full-pool paged runs, and
    preemptions actually happened (the scenario is not vacuous)."""
    cfg, params = small_model
    prompts = _colliding_prompts(cfg)
    sp = SamplingParams(temperature=temperature, top_k=8, max_new_tokens=16)
    _, contig = _streams(cfg, params, prompts, sp,
                         cache_layout="contiguous", prefill_chunk=chunk)
    _, paged = _streams(cfg, params, prompts, sp, prefill_chunk=chunk)
    eng, over = _streams(cfg, params, prompts, sp, prefill_chunk=chunk,
                         kv_num_blocks=HALF_POOL, preemption="recompute")
    assert over == contig
    assert over == paged
    assert len(over) == len(prompts)
    assert eng.preemptions > 0
    assert eng.recompute_tokens > 0
    assert eng.blocks_in_use == 0  # pool drained balanced
    s = eng.latency_summary()
    assert s["preemptions"] == eng.preemptions
    assert s["recompute_tokens"] == eng.recompute_tokens
    assert 0.0 < s["pool_occupancy_p50"] <= s["pool_occupancy_p95"] <= 1.0


def test_preemption_with_prefix_cache_keeps_shared_blocks(small_model):
    """Overcommit on a shared-prefix workload: streams still match the
    uncontended prefix-cached run, sharers still hit, and refcounts
    balance — preemption decrefs shared blocks instead of reclaiming
    them from under a live reader (the pool asserts on that)."""
    cfg, params = small_model
    arrivals = shared_prefix_trace(
        cfg.vocab_size, num_requests=6, shared_prefix_len=16,
        num_prefixes=1, suffix_len=8, max_new=16, temperature=0.7,
        top_k=8, seed=3)
    prompts = [a.prompt for a in arrivals]
    sp = arrivals[0].params
    _, base = _streams(cfg, params, prompts, sp, prefill_chunk=8,
                       kv_num_blocks=64, prefix_cache=True)
    eng, over = _streams(cfg, params, prompts, sp, prefill_chunk=8,
                         kv_num_blocks=HALF_POOL, prefix_cache=True,
                         preemption="recompute")
    assert over == base
    assert eng.preemptions > 0
    assert eng.prefix_hits > 0
    assert eng.blocks_in_use == 0
    assert all(r == 0 for r in eng._pool.refs.values())


def test_preempted_mid_prefill_restarts_cold(small_model):
    """A victim parked before its first token re-admits like a fresh
    request (nothing emitted, nothing to resume) and still matches."""
    cfg, params = small_model
    prompts = _colliding_prompts(cfg, n=4, plen=24)
    sp = SamplingParams(temperature=0.7, top_k=8, max_new_tokens=16)
    # chunk=1 keeps cursors open for many steps, so growth-driven
    # preemption can catch a slot mid-prefill
    _, base = _streams(cfg, params, prompts, sp, prefill_chunk=1)
    eng, over = _streams(cfg, params, prompts, sp, prefill_chunk=1,
                         kv_num_blocks=HALF_POOL, preemption="recompute")
    assert over == base
    assert len(over) == len(prompts)
    assert eng.preemptions > 0


# -- scheduler invariants ----------------------------------------------------

def test_head_of_line_never_preempted(small_model):
    """Victims are LIFO by admission order and the oldest in-flight
    request is exempt — the progress guarantee that makes the engine
    drain under any overcommit."""
    cfg, params = small_model

    victims = []

    class Spy(ServingEngine):
        def _preempt(self, slot):
            live = [r.admit_seq for r in self.slots if r is not None]
            victims.append((self.slots[slot].admit_seq, sorted(live)))
            super()._preempt(slot)

    eng = Spy(cfg, params, max_batch=2, max_len=64, prompt_bucket=8,
              cache_layout="paged", kv_block_size=BS,
              kv_num_blocks=HALF_POOL, preemption="recompute")
    for p in _colliding_prompts(cfg):
        eng.submit(p, SamplingParams(max_new_tokens=16))
    eng.run()
    assert victims, "overcommit scenario never preempted"
    for seq, live in victims:
        assert seq == max(live), "victim was not the newest admitted"
        assert seq != min(live), "head-of-line request preempted"
    assert len(eng.finished) == 6


def test_preempted_requests_block_new_admissions(small_model):
    """A parked request re-admits ahead of the waiting queue — queue
    admissions only ever run with the preempted queue empty, so new
    arrivals cannot starve a request that already emitted tokens."""
    cfg, params = small_model
    parked_seen = []

    class Spy(ServingEngine):
        def _admit_batch(self, reqs, slots_for, plen):
            assert not self._preempted, (
                "queue admission bypassed parked requests")
            super()._admit_batch(reqs, slots_for, plen)

        def _try_readmit(self):
            parked_seen.append(len(self._preempted))
            return super()._try_readmit()

    eng = Spy(cfg, params, max_batch=2, max_len=64, prompt_bucket=8,
              cache_layout="paged", kv_block_size=BS,
              kv_num_blocks=HALF_POOL, preemption="recompute")
    for p in _colliding_prompts(cfg, n=8):
        eng.submit(p, SamplingParams(max_new_tokens=16))
    eng.run()
    assert parked_seen, "no request was ever parked"
    assert len(eng.finished) == 8
    # every request finished despite the churn
    assert sorted(r.uid for r in eng.finished) == list(range(8))


# -- auto sizing -------------------------------------------------------------

def test_suggest_num_blocks_sizes_from_p95():
    # 20 sequences of 40 tokens: p95 = 40 -> 5 blocks + 1 slack per slot,
    # 2 slots + garbage = 13; well under the worst case (2*8+1 = 17)
    n = cache_lib.suggest_num_blocks([40] * 20, 8, 64, 2)
    assert n == 2 * (5 + 1) + 1
    # clamps: tiny workload never drops below one worst-case request +
    # garbage; a huge one never exceeds the worst-case default
    assert cache_lib.suggest_num_blocks([8], 8, 64, 2) == 9
    assert cache_lib.suggest_num_blocks([10_000] * 4, 8, 64, 2) == 17
    # empty trace falls back to the worst case
    assert cache_lib.suggest_num_blocks([], 8, 64, 2) == 17
    # lighter estimated concurrency shrinks the suggestion
    assert (cache_lib.suggest_num_blocks([40] * 20, 8, 64, 4, concurrency=1)
            < cache_lib.suggest_num_blocks([40] * 20, 8, 64, 4))


def test_estimate_concurrency_from_trace():
    vocab = 128
    burst = bursty_trace(vocab, bursts=1, burst_size=6, prompt_len=16,
                         max_new=8)
    assert estimate_concurrency(burst, max_batch=4) == 4  # closed loop
    spread = bursty_trace(vocab, bursts=6, burst_size=1, gap_s=100.0,
                          prompt_len=16, max_new=8)
    assert estimate_concurrency(spread, max_batch=4) == 1  # no overlap
    assert estimate_concurrency([], max_batch=4) == 1


def test_auto_sized_pool_plus_preemption_completes(small_model):
    """The intended pairing end to end: an auto-sized (sub-worst-case)
    pool survives a bursty trace via preemption and matches the
    uncontended streams."""
    cfg, params = small_model
    arrivals = bursty_trace(cfg.vocab_size, bursts=2, burst_size=3,
                            prompt_len=24, max_new=16, seed=1)
    prompts = [a.prompt for a in arrivals]
    sp = arrivals[0].params
    seq_lens = [len(p) + sp.max_new_tokens for p in prompts]
    n = cache_lib.suggest_num_blocks(
        seq_lens, BS, 64, 2, concurrency=estimate_concurrency(arrivals, 2))
    assert n < cache_lib.default_num_blocks(2, 64, BS)
    _, base = _streams(cfg, params, prompts, sp)
    eng, got = _streams(cfg, params, prompts, sp, kv_num_blocks=n,
                        preemption="recompute")
    assert got == base
    assert len(got) == len(prompts)


# -- gating + CLI ------------------------------------------------------------

def test_preemption_requires_paged_layout(small_model):
    cfg, params = small_model
    with pytest.raises(ValueError, match="paged"):
        ServingEngine(cfg, params, preemption="recompute")


def test_serve_cli_auto_blocks_and_preemption():
    from repro.launch.serve import main

    assert main(["--arch", "qwen1.5-0.5b", "--smoke", "--requests", "6",
                 "--max-new", "16", "--max-batch", "2", "--max-len", "64",
                 "--cache-layout", "paged", "--kv-block-size", "8",
                 "--kv-num-blocks", "auto", "--preemption", "recompute",
                 "--bursty", "--burst-size", "3", "--prompt-len-mean", "24",
                 "--power-reader", "none"]) == 0
