"""Sharding rules + partition helpers.  Multi-device behavior runs in a
subprocess with forced host device count (the main pytest process must keep
seeing 1 device per the assignment)."""

import json
import os
import subprocess
import sys
import textwrap

import jax
import pytest
from jax.sharding import PartitionSpec

from repro.sharding import rules

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_logical_to_pspec_no_mesh_is_empty():
    assert rules.logical_to_pspec(("embed", "ffn")) == PartitionSpec()


def test_dryrun_bookkeeping_logic():
    from repro.configs import SHAPES, get_config
    from repro.launch.dryrun import input_specs, should_skip

    # long_500k skips full-attention archs, runs ssm/hybrid
    assert should_skip(get_config("llama3.1-8b"), SHAPES["long_500k"])
    assert should_skip(get_config("qwen3-moe-30b-a3b"), SHAPES["long_500k"])
    assert should_skip(get_config("xlstm-1.3b"), SHAPES["long_500k"]) is None
    assert should_skip(get_config("recurrentgemma-2b"), SHAPES["long_500k"]) is None
    # every non-skip cell produces well-formed specs
    for arch in ("minitron-4b", "llava-next-34b", "seamless-m4t-large-v2"):
        cfg = get_config(arch)
        b = input_specs(cfg, SHAPES["train_4k"])
        assert b["tokens"].shape[0] == 256
        total = b["tokens"].shape[1] + (
            cfg.num_vision_tokens or (b["tokens"].shape[1] if cfg.is_encdec else 0))
        assert total == 4096
        d = input_specs(cfg, SHAPES["decode_32k"])
        assert d["token"].shape == (128, 1)
        assert d["positions"].shape == (128,)


_SUBPROCESS_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec
    from repro.configs import get_config
    from repro.models import model as model_lib
    from repro.sharding import partition, rules
    from repro.training import step as step_lib, checkpoint as ckpt_lib
    from repro.training.optimizer import AdamW, constant_schedule

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = get_config("tinyllama-1.1b", smoke=True).replace(
        d_model=64, num_heads=8, num_kv_heads=4, d_ff=128)
    out = {}

    with rules.use_mesh(mesh):
        shapes, axes = model_lib.param_axes(cfg)
        sh = partition.param_shardings(axes, shapes, mesh)
        # ffn weights shard on model, embed dim on data
        wg = sh["decoder"]["groups"]["0"]["mlp"]["wg"]
        out["wg_spec"] = str(wg.spec)
        emb = sh["embed"]["table"]
        out["emb_spec"] = str(emb.spec)

        # compile + run one sharded train step on the 2x4 mesh
        opt = AdamW(schedule=constant_schedule(1e-3))
        state, _ = step_lib.init_state(cfg, opt, jax.random.PRNGKey(0))
        step = jax.jit(step_lib.make_train_step(cfg, opt, remat=False))
        rng = np.random.default_rng(0)
        batch = {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 16))),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 16))),
        }
        state, metrics = step(state, batch)
        out["loss"] = float(metrics["loss"])

        # elastic restore: save under 2x4, restore under 8x1
        import tempfile
        d = tempfile.mkdtemp()
        ckpt_lib.save(d, 1, {"params": state.params})

    mesh2 = jax.make_mesh((8,), ("data",))
    with rules.use_mesh(mesh2):
        shapes2, axes2 = model_lib.param_axes(cfg)
        sh2 = partition.param_shardings(axes2, shapes2, mesh2)
        restored, _ = ckpt_lib.restore(d, {"params": shapes2},
                                       shardings={"params": sh2})
        diff = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(
            jax.tree.leaves(restored["params"]),
            jax.tree.leaves(state.params)))
        out["elastic_restore_diff"] = diff
    print(json.dumps(out))
""")


@pytest.mark.slow
def test_sharded_train_and_elastic_restore_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _SUBPROCESS_SCRIPT],
                          capture_output=True, text=True, env=env, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert "model" in out["wg_spec"]
    assert out["elastic_restore_diff"] == 0.0
    assert out["loss"] > 0
