"""Block-level prefix caching: correctness and pool accounting.

The contract: enabling the prefix cache changes *what prefill work runs*,
never *what tokens come out*.  A request served from resident prefix
blocks must emit the same stream as a cold request for the same seed —
{greedy, sampled} x {chunked, unchunked} — while refcounts, eviction, and
the free stack stay balanced.
"""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import cache as cache_lib
from repro.models import model as model_lib
from repro.models.config import ModelConfig
from repro.serving.engine import ServingEngine
from repro.serving.sampling import SamplingParams
from repro.serving.workload import shared_prefix_trace

BS = 8  # kv block size used throughout: 64-token max_len -> 8 blocks/slot


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("tinyllama-1.1b", smoke=True)
    params, _ = model_lib.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _engine(cfg, params, **kw):
    kw.setdefault("cache_layout", "paged")
    kw.setdefault("kv_block_size", BS)
    return ServingEngine(cfg, params, max_batch=2, max_len=64,
                        prompt_bucket=8, **kw)


def _streams(cfg, params, arrivals, **kw):
    eng = _engine(cfg, params, **kw)
    for a in arrivals:
        eng.submit(a.prompt, a.params)
    finished = eng.run()
    return eng, {r.uid: list(r.output_tokens) for r in finished}


# -- hashing -----------------------------------------------------------------

def test_hash_token_blocks_chains_and_skips_partial_tail():
    toks = np.arange(20, dtype=np.int32)
    hashes = cache_lib.hash_token_blocks(toks, 8)
    assert len(hashes) == 2  # 20 tokens -> 2 full blocks, tail unhashed
    # same prefix, same hashes; a one-token change in block 0 changes both
    # (chained), a change in block 1 changes only hashes[1]
    same = cache_lib.hash_token_blocks(np.arange(23, dtype=np.int32), 8)
    assert same == hashes
    flip0 = toks.copy(); flip0[0] += 1
    flip1 = toks.copy(); flip1[9] += 1
    assert cache_lib.hash_token_blocks(flip0, 8)[0] != hashes[0]
    assert cache_lib.hash_token_blocks(flip0, 8)[1] != hashes[1]
    assert cache_lib.hash_token_blocks(flip1, 8)[0] == hashes[0]
    assert cache_lib.hash_token_blocks(flip1, 8)[1] != hashes[1]


# -- stream equivalence ------------------------------------------------------

@pytest.mark.parametrize("temperature", [0.0, 0.7])
@pytest.mark.parametrize("chunk", [0, 8])
def test_prefix_cached_streams_match_cold(small_model, chunk, temperature):
    """Warm engines emit the cold engine's exact streams — and actually
    hit: blocks are reused and prefill tokens skipped."""
    cfg, params = small_model
    arrivals = shared_prefix_trace(
        cfg.vocab_size, num_requests=6, shared_prefix_len=24, num_prefixes=2,
        suffix_len=8, max_new=6, temperature=temperature, top_k=8, seed=3)
    _, base = _streams(cfg, params, arrivals, kv_num_blocks=64,
                       prefill_chunk=chunk)
    eng, got = _streams(cfg, params, arrivals, kv_num_blocks=64,
                        prefill_chunk=chunk, prefix_cache=True)
    assert got == base
    assert eng.prefix_hits > 0
    assert eng.prefix_blocks_reused > 0
    assert eng.prefill_tokens_skipped > 0
    assert eng.blocks_in_use == 0  # every live block returned at drain
    s = eng.latency_summary()
    assert s["prefix_hit_rate"] == eng.prefix_hits / eng.prefix_lookups
    assert s["prefill_tokens_skipped"] == eng.prefill_tokens_skipped


def test_warm_request_skips_exactly_the_shared_prefix(small_model):
    """Two same-prefix requests back to back: the second reuses every full
    prefix block (resurrected from the evictable pool after the first
    finished) and recomputes only the suffix + partial tail."""
    cfg, params = small_model
    rng = np.random.default_rng(11)
    prefix = rng.integers(0, cfg.vocab_size, 24).astype(np.int32)

    eng = _engine(cfg, params, kv_num_blocks=64, prefix_cache=True)
    for _ in range(2):
        suffix = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
        eng.submit(np.concatenate([prefix, suffix]),
                   SamplingParams(max_new_tokens=4))
        eng.run()
    # plen = 32, bs = 8: full blocks cover 0..31, the lookup cap keeps the
    # last one private, so the warm request reuses blocks 0..2 = 24 tokens
    assert eng.prefix_hits == 1
    assert eng.prefix_blocks_reused == 3
    assert eng.prefill_tokens_skipped == 24
    # the shared blocks parked back on the evictable LRU with refs == 0
    assert eng.blocks_in_use == 0
    assert all(r == 0 for r in eng._pool.refs.values())


def test_cow_tail_block_never_shared(small_model):
    """A block-aligned prompt registers all its full blocks, but the
    lookup cap keeps an equal-length sharer from hitting the final one —
    it recomputes the block holding its last prompt position privately
    (first-token logits come from there), and its decode writes land in
    the next, private block."""
    cfg, params = small_model
    rng = np.random.default_rng(12)
    prompt = rng.integers(0, cfg.vocab_size, 32).astype(np.int32)  # 4 blocks
    eng = _engine(cfg, params, kv_num_blocks=64, prefix_cache=True)
    for _ in range(2):
        eng.submit(prompt, SamplingParams(max_new_tokens=4))
        eng.run()
    # identical 32-token prompts: all 4 full blocks are registered, but the
    # hit is capped at (plen-1)//bs = 3 blocks
    assert eng.prefix_blocks_reused == 3
    assert eng.prefill_tokens_skipped == 24
    assert len(eng.finished) == 2
    assert eng.finished[0].output_tokens == eng.finished[1].output_tokens


def test_eviction_under_pool_pressure(small_model):
    """Distinct prompts cycling through a minimal pool: cached blocks are
    evicted LRU to satisfy new admissions, nothing leaks, and evicted
    hashes stop matching."""
    cfg, params = small_model
    # minimal legal pool: one worst-case request (8 blocks) + garbage
    eng = _engine(cfg, params, kv_num_blocks=9, prefix_cache=True)
    rng = np.random.default_rng(13)
    for _ in range(4):
        eng.submit(rng.integers(0, cfg.vocab_size, 32),
                   SamplingParams(max_new_tokens=2))
    finished = eng.run()
    assert len(finished) == 4
    assert eng._pool.evictions > 0
    assert eng.prefix_hits == 0  # all prompts distinct: no false sharing
    assert eng.blocks_in_use == 0
    assert len(eng._pool.free_stack) + len(eng._pool.evictable) == 8
    # registry is consistent: every registered block maps back to its hash
    assert all(eng._pool.block_of[h] == b
               for b, h in eng._pool.hash_of.items())


def test_prefix_cache_survives_concurrent_sharers(small_model):
    """Two live requests sharing prefix blocks: refcounts reach 2, and the
    blocks only become evictable after both finish."""
    cfg, params = small_model
    rng = np.random.default_rng(14)
    prefix = rng.integers(0, cfg.vocab_size, 24).astype(np.int32)
    eng = _engine(cfg, params, kv_num_blocks=64, prefix_cache=True,
                  prefill_chunk=8)
    suffix = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    eng.submit(np.concatenate([prefix, suffix]),
               SamplingParams(max_new_tokens=30))
    for _ in range(6):  # first sharer's prefix blocks land and become ready
        eng.step()
    suffix = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    eng.submit(np.concatenate([prefix, suffix]),
               SamplingParams(max_new_tokens=30))
    saw_shared = False
    for _ in range(200):
        if not eng.busy:
            break
        eng.step()
        if any(r == 2 for r in eng._pool.refs.values()):
            saw_shared = True
    assert saw_shared, "prefix blocks never reached two live readers"
    assert len(eng.finished) == 2
    assert all(r == 0 for r in eng._pool.refs.values())


def test_eviction_degrades_chains_from_the_tail(small_model):
    """Freed chains park tail-first on the evictable LRU, so pool pressure
    evicts a cached prefix from the right: a later same-prefix request
    still hits the surviving head blocks (evicting the head would strand
    the whole chain — lookups only match a leading run)."""
    cfg, params = small_model
    eng = _engine(cfg, params, kv_num_blocks=9, prefix_cache=True)
    rng = np.random.default_rng(15)
    prompt_a = rng.integers(0, cfg.vocab_size, 32).astype(np.int32)
    eng.submit(prompt_a, SamplingParams(max_new_tokens=2))   # registers 4
    eng.run()
    # a distinct request forces one eviction (needs 5, only 4 free)
    eng.submit(rng.integers(0, cfg.vocab_size, 32),
               SamplingParams(max_new_tokens=2))
    eng.run()
    assert eng._pool.evictions >= 1
    # the same prefix again: the lookup-cap'd 3-block head must survive
    eng.submit(prompt_a, SamplingParams(max_new_tokens=2))
    eng.run()
    assert eng.prefix_hits == 1
    assert eng.prefix_blocks_reused == 3
    assert eng.prefill_tokens_skipped == 24


# -- gating ------------------------------------------------------------------

def test_prefix_cache_requires_paged_layout(small_model):
    cfg, params = small_model
    with pytest.raises(ValueError, match="paged"):
        ServingEngine(cfg, params, prefix_cache=True)


def test_prefix_cache_rejects_per_slot_state():
    """Sliding-window (and recurrent) stacks keep per-slot cache rows a
    skipped prefill would leave stale — the engine refuses rather than
    serving garbage."""
    cfg = ModelConfig(
        name="toy-hybrid", num_layers=2, d_model=32, num_heads=2,
        num_kv_heads=2, d_ff=64, vocab_size=128,
        block_pattern=("attn", "local_attn"), sliding_window=12,
        dtype="float32", param_dtype="float32",
    ).validate()
    params, _ = model_lib.init(cfg, jax.random.PRNGKey(1))
    with pytest.raises(ValueError, match="local_attn"):
        ServingEngine(cfg, params, cache_layout="paged", prefix_cache=True)


def test_small_pool_error_names_flag_and_minimum(small_model):
    """An over-small pool must tell the operator which flag to turn and
    the computed minimum, not just the block count."""
    cfg, params = small_model
    with pytest.raises(ValueError, match=r"--kv-num-blocks.*>= 5"):
        ServingEngine(cfg, params, cache_layout="paged", max_len=64,
                      kv_block_size=16, kv_num_blocks=2)


# -- CLI ---------------------------------------------------------------------

def test_serve_driver_prefix_cache():
    from repro.launch.serve import main

    assert main(["--arch", "qwen1.5-0.5b", "--smoke", "--requests", "4",
                 "--max-new", "4", "--max-batch", "2", "--max-len", "64",
                 "--cache-layout", "paged", "--prefix-cache",
                 "--shared-prefix-len", "24", "--shared-prefixes", "1",
                 "--power-reader", "none"]) == 0
