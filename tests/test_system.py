"""End-to-end behaviour tests for the full system (paper workflow).

The ELANA workflow: build any registered model behind the one-call API,
profile size/cache analytically, measure latency+energy on the host
device, estimate on target hardware, and export a kernel timeline —
then train and serve the same model through the production drivers.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.profiler import Elana
from repro.core import energy as energy_lib


def test_elana_full_workflow_smoke(tmp_path):
    """The complete paper §2 feature set against one small model."""
    e = Elana("qwen1.5-0.5b", smoke=True)

    # §2.2 sizes
    size = e.size_report()
    assert size.param_count > 0
    cache = e.cache_report(batch=2, seq_len=64)
    assert cache.kv_bytes > 0

    # §2.3 measured latency (real wall-clock on CPU)
    m = e.measure(batch=1, prompt_len=16, gen_len=4, iters=2)
    assert m["ttft_ms"] > 0 and m["tpot_ms"] > 0
    # TTLT decomposition: ttlt ≈ ttft + (gen-1) * tpot (loose: host jitter)
    expected = m["ttft_ms"] + 3 * m["tpot_ms"]
    assert m["ttlt_ms"] < expected * 5 + 50

    # §2.4 energy via a synthetic 10 Hz sampler
    m2 = e.measure(batch=1, prompt_len=16, gen_len=4, iters=2,
                   power_reader=energy_lib.SyntheticReader(lambda t: 42.0))
    assert m2["j_per_token"] > 0

    # §2.3/2.4 estimator mode on every registered hardware target
    for hw in ("a6000", "jetson-orin-nano", "jetson-agx-thor", "tpu-v5e"):
        est = e.estimate(hardware=hw, batch=1, prompt_len=128, gen_len=128)
        assert est.tpot.latency_s > 0 and est.ttlt.joules > 0

    # §2.5 perfetto trace
    path = str(tmp_path / "t.json")
    summary = e.trace(path, phase="decode", seq_len=128)
    assert os.path.exists(path) and summary["total_s"] > 0


def test_elana_custom_builder_hook():
    """The paper's `_build_model_and_tokenizer` extension point."""
    from repro.configs import get_config
    from repro.models import model as model_lib

    def builder():
        cfg = get_config("tinyllama-1.1b", smoke=True)
        params, _ = model_lib.init(cfg, jax.random.PRNGKey(7))
        return cfg, params

    e = Elana(builder=builder)
    assert e.size_report().param_count == sum(
        p.size for p in jax.tree.leaves(e.params))
    m = e.measure(batch=1, prompt_len=8, gen_len=2, iters=1)
    assert m["ttft_ms"] > 0


def test_cli_end_to_end(capsys):
    from repro.cli import main

    assert main(["archs"]) == 0
    assert main(["size", "--arch", "llama3.1-8b"]) == 0
    out = capsys.readouterr().out
    assert "16.06 GB" in out
    assert main(["cache", "--arch", "nemotron-h-8b", "--batch", "128",
                 "--seq-len", "2048"]) == 0
    assert main(["estimate", "--arch", "qwen2.5-7b", "--hardware", "a6000",
                 "--batch", "1", "--prompt", "512", "--gen", "512"]) == 0
    out = capsys.readouterr().out
    assert "TPOT" in out


def test_measured_mode_scaling_sanity():
    """More tokens must cost more wall-clock (measured mode is real)."""
    e = Elana("qwen1.5-0.5b", smoke=True)
    lp = e._latency_profiler()
    t_short = lp.ttft(1, 8, iters=3).mean_s
    t_long = lp.ttft(1, 64, iters=3).mean_s
    assert t_long > t_short * 1.2
