"""Docs stay honest: internal links resolve and the fenced ``bash``
snippets that exercise ``--help`` paths actually run.

Scope is deliberate: snippets that *train models or serve traffic* are
exercised by the test/benchmark suites; what docs rot first is entry-point
names and flags, which the ``--help`` invocations cover cheaply."""

import os
import pathlib
import re
import subprocess

import pytest

pytestmark = pytest.mark.docs

ROOT = pathlib.Path(__file__).resolve().parents[1]
DOC_FILES = [ROOT / "README.md"] + sorted((ROOT / "docs").glob("*.md"))

_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_BASH_BLOCK = re.compile(r"```bash\n(.*?)```", re.S)


def _help_commands():
    cmds = []
    for path in DOC_FILES:
        for block in _BASH_BLOCK.findall(path.read_text()):
            for line in block.splitlines():
                line = line.strip()
                if line.startswith("#") or "--help" not in line:
                    continue
                cmds.append((path.name, line))
    return cmds


def test_docs_exist_and_cross_link():
    """README links the docs; each doc links back (acceptance: README and
    docs/ exist and are linked from each other)."""
    assert DOC_FILES, "no docs found"
    readme = (ROOT / "README.md").read_text()
    assert "docs/serving.md" in readme and "docs/benchmarks.md" in readme
    for name in ("serving.md", "benchmarks.md"):
        assert "README.md" in (ROOT / "docs" / name).read_text(), (
            f"docs/{name} does not link back to README.md")


@pytest.mark.parametrize("path", DOC_FILES, ids=lambda p: p.name)
def test_internal_links_resolve(path):
    """Every relative markdown link points at a file that exists."""
    for link in _LINK.findall(path.read_text()):
        if link.startswith(("http://", "https://", "mailto:")):
            continue
        target = link.split("#", 1)[0]
        if not target:  # same-file anchor
            continue
        resolved = (path.parent / target).resolve()
        assert resolved.exists(), f"{path.name}: broken link {link!r}"


def test_docs_have_runnable_help_snippets():
    """The docs advertise at least one runnable --help entry point (the
    thing the CI docs job exists to keep working)."""
    assert _help_commands()


@pytest.mark.parametrize(
    "doc,cmd", _help_commands(),
    ids=[f"{d}:{c.split()[-2].split('.')[-1]}-{i}"
         for i, (d, c) in enumerate(_help_commands())])
def test_help_snippets_run(doc, cmd):
    env = dict(os.environ)
    env["PYTHONPATH"] = (str(ROOT / "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    proc = subprocess.run(cmd, shell=True, cwd=ROOT, env=env,
                          capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, (
        f"{doc}: `{cmd}` exited {proc.returncode}\n{proc.stderr[-2000:]}")
