"""Docs stay honest: internal links resolve and the fenced ``bash``
snippets that exercise ``--help`` paths actually run.

Scope is deliberate: snippets that *train models or serve traffic* are
exercised by the test/benchmark suites; what docs rot first is entry-point
names and flags, which the ``--help`` invocations cover cheaply."""

import os
import pathlib
import re
import subprocess

import pytest

pytestmark = pytest.mark.docs

ROOT = pathlib.Path(__file__).resolve().parents[1]
DOC_FILES = [ROOT / "README.md"] + sorted((ROOT / "docs").glob("*.md"))

_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_BASH_BLOCK = re.compile(r"```bash\n(.*?)```", re.S)
_FLAG = re.compile(r"(?<![\w-])--[a-z][a-z0-9-]*")

# snippet-flag allowlist: fenced bash lines invoking these modules have
# every --flag checked against the module's live --help output (flags are
# what rot right after entry-point names).  Modules with subcommands
# (repro.cli) are exempt — their top-level --help doesn't list subcommand
# flags.
_FLAG_CHECKED_MODULES = ("repro.launch.serve", "repro.launch.bench_serve",
                         "benchmarks.run")


def _help_commands():
    cmds = []
    for path in DOC_FILES:
        for block in _BASH_BLOCK.findall(path.read_text()):
            for line in block.splitlines():
                line = line.strip()
                if line.startswith("#") or "--help" not in line:
                    continue
                cmds.append((path.name, line))
    return cmds


def test_docs_exist_and_cross_link():
    """README links the docs; each doc links back (acceptance: README and
    docs/ exist and are linked from each other)."""
    assert DOC_FILES, "no docs found"
    readme = (ROOT / "README.md").read_text()
    assert "docs/serving.md" in readme and "docs/benchmarks.md" in readme
    assert "docs/tuning.md" in readme
    for name in ("serving.md", "benchmarks.md", "tuning.md"):
        assert "README.md" in (ROOT / "docs" / name).read_text(), (
            f"docs/{name} does not link back to README.md")


@pytest.mark.parametrize("path", DOC_FILES, ids=lambda p: p.name)
def test_internal_links_resolve(path):
    """Every relative markdown link points at a file that exists."""
    for link in _LINK.findall(path.read_text()):
        if link.startswith(("http://", "https://", "mailto:")):
            continue
        target = link.split("#", 1)[0]
        if not target:  # same-file anchor
            continue
        resolved = (path.parent / target).resolve()
        assert resolved.exists(), f"{path.name}: broken link {link!r}"


def test_docs_have_runnable_help_snippets():
    """The docs advertise at least one runnable --help entry point (the
    thing the CI docs job exists to keep working)."""
    assert _help_commands()


def _doc_flags():
    """(doc, module, flag) per --flag used in a fenced bash snippet that
    invokes an allowlisted module."""
    out = []
    for path in DOC_FILES:
        for block in _BASH_BLOCK.findall(path.read_text()):
            # snippets wrap with backslash-newline; rejoin before parsing
            for line in block.replace("\\\n", " ").splitlines():
                for mod in _FLAG_CHECKED_MODULES:
                    if f"-m {mod}" in line:
                        out.extend((path.name, mod, flag)
                                   for flag in _FLAG.findall(line))
    return out


def _module_help(mod):
    env = dict(os.environ)
    env["PYTHONPATH"] = (str(ROOT / "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    proc = subprocess.run(f"python -m {mod} --help", shell=True, cwd=ROOT,
                          env=env, capture_output=True, text=True,
                          timeout=300)
    assert proc.returncode == 0, proc.stderr[-2000:]
    return proc.stdout


def test_serving_doc_covers_multi_device():
    """docs/serving.md documents the tensor-parallel path with live
    snippets: a --tp invocation under the forced-host XLA_FLAGS (those
    flags go through the snippet-flag check above) and the per-device
    summary keys the glossary promises."""
    text = (ROOT / "docs" / "serving.md").read_text()
    tp_snippets = [block for block in _BASH_BLOCK.findall(text)
                   if "--tp" in block]
    assert tp_snippets, "docs/serving.md has no fenced --tp snippet"
    assert any("xla_force_host_platform_device_count" in b
               for b in tp_snippets), (
        "the --tp snippets never show how to force a multi-device host")
    for key in ("joules_per_device", "kv_bytes_peak_per_device",
                "DeviceMonitorGroup"):
        assert key in text, f"docs/serving.md stopped mentioning {key}"


def test_doc_snippet_flags_are_registered():
    """Every --flag a doc snippet passes to an allowlisted entry point
    exists in that entry point's --help (catches flags renamed or removed
    after the docs were written — e.g. --kv-num-blocks, --preemption,
    --bursty)."""
    flags = _doc_flags()
    assert flags, "no allowlisted snippet flags found in the docs"
    helps = {mod: _module_help(mod)
             for mod in {m for _, m, _ in flags}}
    missing = [(doc, mod, flag) for doc, mod, flag in flags
               if flag != "--help" and flag not in helps[mod]]
    assert not missing, f"doc flags unknown to their entry point: {missing}"


@pytest.mark.parametrize(
    "doc,cmd", _help_commands(),
    ids=[f"{d}:{c.split()[-2].split('.')[-1]}-{i}"
         for i, (d, c) in enumerate(_help_commands())])
def test_help_snippets_run(doc, cmd):
    env = dict(os.environ)
    env["PYTHONPATH"] = (str(ROOT / "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    proc = subprocess.run(cmd, shell=True, cwd=ROOT, env=env,
                          capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, (
        f"{doc}: `{cmd}` exited {proc.returncode}\n{proc.stderr[-2000:]}")
