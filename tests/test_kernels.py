"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs ref.py oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", False)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


def _mk(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,Hq,Hkv,D", [
    (128, 4, 4, 64),     # MHA
    (128, 8, 2, 64),     # GQA 4:1
    (256, 4, 1, 128),    # MQA
    (96, 4, 2, 80),      # ragged block sizes + odd head dim
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 32), (False, 0)])
def test_flash_attention_sweep(S, Hq, Hkv, D, dtype, causal, window):
    from repro.kernels.flash_attention import ops, ref

    key = jax.random.PRNGKey(hash((S, Hq, Hkv, D, causal, window)) % 2**31)
    B = 2
    q = _mk(key, (B, S, Hq, D), dtype)
    k = _mk(jax.random.fold_in(key, 1), (B, S, Hkv, D), dtype)
    v = _mk(jax.random.fold_in(key, 2), (B, S, Hkv, D), dtype)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S)).astype(jnp.int32)
    o_ref = ref.attention(q, k, v, q_positions=pos, k_positions=pos,
                          causal=causal, window=window)
    o_pal = ops.flash_attention(q, k, v, q_positions=pos, k_positions=pos,
                                causal=causal, window=window, interpret=True)
    np.testing.assert_allclose(
        np.asarray(o_pal, np.float32), np.asarray(o_ref, np.float32), **_tol(dtype))


def test_flash_attention_softcap():
    from repro.kernels.flash_attention import ops, ref

    key = jax.random.PRNGKey(3)
    B, S, H, D = 1, 64, 2, 32
    q, k, v = (_mk(jax.random.fold_in(key, i), (B, S, H, D), jnp.float32)
               for i in range(3))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S)).astype(jnp.int32)
    o_ref = ref.attention(q, k, v, q_positions=pos, k_positions=pos,
                          causal=True, softcap=30.0)
    o_pal = ops.flash_attention(q, k, v, q_positions=pos, k_positions=pos,
                                causal=True, softcap=30.0, interpret=True)
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_ref), rtol=2e-5, atol=2e-5)


def test_flash_attention_grad_matches_ref():
    from repro.kernels.flash_attention import ops, ref

    key = jax.random.PRNGKey(4)
    B, S, Hq, Hkv, D = 2, 64, 4, 2, 32
    q = _mk(key, (B, S, Hq, D), jnp.float32)
    k = _mk(jax.random.fold_in(key, 1), (B, S, Hkv, D), jnp.float32)
    v = _mk(jax.random.fold_in(key, 2), (B, S, Hkv, D), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S)).astype(jnp.int32)

    def loss_ref(q, k, v):
        return ref.attention(q, k, v, q_positions=pos, k_positions=pos,
                             causal=True).sum()

    def loss_pal(q, k, v):
        return ops.flash_attention(q, k, v, q_positions=pos, k_positions=pos,
                                   causal=True, interpret=True).sum()

    for gr, gp in zip(jax.grad(loss_ref, (0, 1, 2))(q, k, v),
                      jax.grad(loss_pal, (0, 1, 2))(q, k, v)):
        np.testing.assert_allclose(np.asarray(gp), np.asarray(gr),
                                   rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("L,Hq,Hkv,D", [
    (256, 8, 2, 64), (512, 4, 4, 128), (128, 16, 1, 64), (96, 4, 2, 80),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(L, Hq, Hkv, D, dtype):
    from repro.kernels.decode_attention import ops, ref

    key = jax.random.PRNGKey(hash((L, Hq, Hkv, D)) % 2**31)
    B = 3
    q = _mk(key, (B, 1, Hq, D), dtype)
    kc = _mk(jax.random.fold_in(key, 1), (B, L, Hkv, D), dtype)
    vc = _mk(jax.random.fold_in(key, 2), (B, L, Hkv, D), dtype)
    qpos = jnp.asarray([[L // 3], [L // 2], [L - 1]], jnp.int32)
    kpos = jnp.broadcast_to(jnp.arange(L)[None], (B, L)).astype(jnp.int32)
    kpos = jnp.where(kpos <= qpos, kpos, -1)   # partially filled cache
    o_ref = ref.decode_attention(q, kc, vc, q_positions=qpos, k_positions=kpos)
    o_pal = ops.decode_attention(q, kc, vc, q_positions=qpos, k_positions=kpos,
                                 interpret=True)
    np.testing.assert_allclose(
        np.asarray(o_pal, np.float32), np.asarray(o_ref, np.float32), **_tol(dtype))


def test_decode_attention_ring_buffer_window():
    """Ring-buffer layout: positions wrap modulo window."""
    from repro.kernels.decode_attention import ops, ref

    key = jax.random.PRNGKey(7)
    B, L, Hkv, Hq, D = 2, 64, 2, 4, 32
    q = _mk(key, (B, 1, Hq, D), jnp.float32)
    kc = _mk(jax.random.fold_in(key, 1), (B, L, Hkv, D), jnp.float32)
    vc = _mk(jax.random.fold_in(key, 2), (B, L, Hkv, D), jnp.float32)
    cur = 150   # decoded beyond the ring: slots hold positions 87..150
    slots = np.arange(L)
    pos_at_slot = cur - ((cur - slots) % L)
    kpos = jnp.broadcast_to(jnp.asarray(pos_at_slot)[None], (B, L)).astype(jnp.int32)
    qpos = jnp.full((B, 1), cur, jnp.int32)
    o_ref = ref.decode_attention(q, kc, vc, q_positions=qpos, k_positions=kpos,
                                 window=L)
    o_pal = ops.decode_attention(q, kc, vc, q_positions=qpos, k_positions=kpos,
                                 window=L, interpret=True)
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window", [0, 24])
def test_paged_decode_attention_matches_oracle(window):
    """Block-pool kernel (scalar-prefetched block tables) vs the gather
    oracle, over shuffled non-contiguous physical blocks."""
    from repro.kernels.decode_attention import ops, ref

    key = jax.random.PRNGKey(11)
    B, Hq, Hkv, D, bs, nb, N = 3, 8, 2, 64, 16, 4, 14
    q = _mk(key, (B, 1, Hq, D), jnp.float32)
    kp = _mk(jax.random.fold_in(key, 1), (N, bs, Hkv, D), jnp.float32)
    vp = _mk(jax.random.fold_in(key, 2), (N, bs, Hkv, D), jnp.float32)
    q_lens = [5, 17, 63]
    rng = np.random.default_rng(0)
    perm = rng.permutation(np.arange(1, N))  # block 0 reserved (garbage)
    tables = np.zeros((B, nb), np.int32)
    ptr = 0
    for b, p in enumerate(q_lens):
        need = (p + 1 + bs - 1) // bs
        tables[b, :need] = perm[ptr:ptr + need]
        ptr += need
    tables = jnp.asarray(tables)
    qpos = jnp.asarray([[p] for p in q_lens], jnp.int32)
    o_ref = ref.paged_decode_attention(
        q, kp, vp, block_tables=tables, q_positions=qpos, window=window)
    o_pal = ops.paged_decode_attention(
        q, kp, vp, block_tables=tables, q_positions=qpos, window=window,
        interpret=True)
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)


def test_paged_decode_attention_matches_contiguous():
    """Identical KV served paged vs contiguous gives identical outputs:
    garbage-block table entries and unwritten block tails are masked."""
    from repro.kernels.decode_attention import ref

    key = jax.random.PRNGKey(3)
    B, L, Hq, Hkv, D, bs = 2, 64, 4, 2, 32, 16
    q = _mk(key, (B, 1, Hq, D), jnp.float32)
    kc = _mk(jax.random.fold_in(key, 1), (B, L, Hkv, D), jnp.float32)
    vc = _mk(jax.random.fold_in(key, 2), (B, L, Hkv, D), jnp.float32)
    qpos = jnp.asarray([[20], [47]], jnp.int32)
    kpos = jnp.broadcast_to(jnp.arange(L)[None], (B, L)).astype(jnp.int32)
    o_contig = ref.decode_attention(q, kc, vc, q_positions=qpos,
                                    k_positions=kpos)
    # pool: block 0 garbage, rows interleaved — row b block j at 1 + j*B + b
    nb = L // bs
    kp = jnp.concatenate([jnp.zeros((1, bs, Hkv, D))] + [
        kc[b, j * bs:(j + 1) * bs][None] for j in range(nb) for b in range(B)
    ])
    vp = jnp.concatenate([jnp.zeros((1, bs, Hkv, D))] + [
        vc[b, j * bs:(j + 1) * bs][None] for j in range(nb) for b in range(B)
    ])
    tables = jnp.asarray(
        [[1 + j * B + b for j in range(nb)] for b in range(B)], jnp.int32)
    o_paged = ref.paged_decode_attention(
        q, kp.astype(kc.dtype), vp.astype(vc.dtype), block_tables=tables,
        q_positions=qpos)
    np.testing.assert_allclose(np.asarray(o_paged), np.asarray(o_contig),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# linear recurrence
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,W", [(256, 128), (512, 160), (64, 512), (100, 96)])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_linear_recurrence_sweep(S, W, dtype):
    from repro.kernels.linear_recurrence import ops, ref

    key = jax.random.PRNGKey(hash((S, W)) % 2**31)
    B = 2
    a = jax.nn.sigmoid(_mk(key, (B, S, W), dtype)) * 0.2 + 0.8
    b = _mk(jax.random.fold_in(key, 1), (B, S, W), dtype) * 0.1
    h0 = _mk(jax.random.fold_in(key, 2), (B, W), dtype)
    h_ref = ref.linear_recurrence(a, b, h0)
    h_pal = ops.linear_recurrence(a, b, h0, interpret=True)
    np.testing.assert_allclose(np.asarray(h_pal), np.asarray(h_ref),
                               rtol=1e-4, atol=1e-5)


def test_linear_recurrence_matches_sequential():
    """Oracle-of-the-oracle: associative scan == naive python loop."""
    from repro.kernels.linear_recurrence import ref

    rng = np.random.default_rng(0)
    B, S, W = 1, 37, 8
    a = rng.uniform(0.8, 1.0, (B, S, W)).astype(np.float32)
    b = rng.standard_normal((B, S, W)).astype(np.float32) * 0.1
    h0 = rng.standard_normal((B, W)).astype(np.float32)
    h = h0.copy()
    expected = []
    for t in range(S):
        h = a[:, t] * h + b[:, t]
        expected.append(h.copy())
    expected = np.stack(expected, axis=1)
    got = np.asarray(ref.linear_recurrence(jnp.asarray(a), jnp.asarray(b),
                                           jnp.asarray(h0)))
    np.testing.assert_allclose(got, expected, rtol=1e-5, atol=1e-6)


def test_linear_recurrence_grad():
    from repro.kernels.linear_recurrence import ops, ref

    key = jax.random.PRNGKey(9)
    B, S, W = 1, 64, 32
    a = jax.nn.sigmoid(_mk(key, (B, S, W), jnp.float32)) * 0.2 + 0.8
    b = _mk(jax.random.fold_in(key, 1), (B, S, W), jnp.float32) * 0.1
    h0 = jnp.zeros((B, W))
    g_ref = jax.grad(lambda b_: ref.linear_recurrence(a, b_, h0).sum())(b)
    g_pal = jax.grad(lambda b_: ops.linear_recurrence(a, b_, h0,
                                                      interpret=True).sum())(b)
    np.testing.assert_allclose(np.asarray(g_pal), np.asarray(g_ref),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(8, 128), (2, 33, 384), (1, 7, 5, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(shape, dtype):
    from repro.kernels.rmsnorm import ops, ref

    key = jax.random.PRNGKey(hash(shape) % 2**31)
    x = _mk(key, shape, dtype)
    s = _mk(jax.random.fold_in(key, 1), (shape[-1],), jnp.float32) * 0.1
    o_ref = ref.rmsnorm(x, s)
    o_pal = ops.rmsnorm(x, s, interpret=True)
    np.testing.assert_allclose(
        np.asarray(o_pal, np.float32), np.asarray(o_ref, np.float32), **_tol(dtype))


def test_rmsnorm_grad():
    from repro.kernels.rmsnorm import ops, ref

    key = jax.random.PRNGKey(11)
    x = _mk(key, (4, 64), jnp.float32)
    s = _mk(jax.random.fold_in(key, 1), (64,), jnp.float32) * 0.1
    g_ref = jax.grad(lambda x_: ref.rmsnorm(x_, s).sum())(x)
    g_pal = jax.grad(lambda x_: ops.rmsnorm(x_, s, interpret=True).sum())(x)
    np.testing.assert_allclose(np.asarray(g_pal), np.asarray(g_ref),
                               rtol=1e-5, atol=1e-6)
