"""Serving engine: slot-based continuous batching, latency accounting,
decode correctness under mixed slot positions."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as model_lib
from repro.serving.engine import ServingEngine
from repro.serving.sampling import SamplingParams


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("tinyllama-1.1b", smoke=True)
    params, _ = model_lib.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_engine_serves_all_requests(small_model):
    cfg, params = small_model
    eng = ServingEngine(cfg, params, max_batch=2, max_len=64, prompt_bucket=8)
    rng = np.random.default_rng(0)
    uids = [eng.submit(rng.integers(0, cfg.vocab_size, 5 + 3 * i),
                       SamplingParams(max_new_tokens=6)) for i in range(5)]
    finished = eng.run()
    assert sorted(r.uid for r in finished) == sorted(uids)
    assert all(len(r.output_tokens) == 6 for r in finished)
    s = eng.latency_summary()
    assert s["requests"] == 5
    assert s["ttlt_ms"] >= s["ttft_ms"] > 0


def test_engine_greedy_matches_reference_decode(small_model):
    """Tokens produced through the engine == tokens from a manual prefill +
    greedy decode loop (per-slot positions are honest)."""
    cfg, params = small_model
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    gen = 5

    # reference: manual loop at batch=1
    cache = model_lib.init_cache(cfg, 1, 64, jnp.dtype(cfg.dtype))
    logits, cache = model_lib.prefill(
        cfg, params, {"tokens": jnp.asarray(prompt)[None]}, cache)
    ref_tokens = [int(jnp.argmax(logits, -1)[0])]
    pos = len(prompt)
    for _ in range(gen - 1):
        tok = jnp.asarray([[ref_tokens[-1]]], jnp.int32)
        logits, cache = model_lib.decode_step(
            cfg, params, tok, jnp.asarray(pos, jnp.int32), cache)
        ref_tokens.append(int(jnp.argmax(logits, -1)[0]))
        pos += 1

    eng = ServingEngine(cfg, params, max_batch=2, max_len=64, prompt_bucket=8)
    eng.submit(prompt, SamplingParams(temperature=0.0, max_new_tokens=gen))
    # a second, longer request sharing the batch must not corrupt slot 0
    eng.submit(rng.integers(0, cfg.vocab_size, 13),
               SamplingParams(temperature=0.0, max_new_tokens=gen))
    finished = eng.run()
    got = next(r for r in finished if r.uid == 0).output_tokens
    assert got == ref_tokens


def test_engine_eos_stops_early(small_model):
    cfg, params = small_model
    eng = ServingEngine(cfg, params, max_batch=1, max_len=64)
    rng = np.random.default_rng(2)
    # pick the model's own first greedy token as "eos" to force a 1-token gen
    prompt = rng.integers(0, cfg.vocab_size, 6)
    eng.submit(prompt, SamplingParams(max_new_tokens=8))
    first = eng.run()[0].output_tokens[0]
    eng2 = ServingEngine(cfg, params, max_batch=1, max_len=64)
    eng2.submit(prompt, SamplingParams(max_new_tokens=8, eos_token=first))
    r = eng2.run()[0]
    assert len(r.output_tokens) == 1 and r.output_tokens[0] == first


def test_serve_driver():
    from repro.launch.serve import main

    assert main(["--arch", "qwen1.5-0.5b", "--smoke", "--requests", "3",
                 "--max-new", "4", "--max-batch", "2", "--max-len", "64"]) == 0
