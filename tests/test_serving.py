"""Serving engine: device-resident continuous batching, latency accounting,
decode correctness under mixed slot positions, per-request energy."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.energy import PowerMonitor, SyntheticReader
from repro.models import model as model_lib
from repro.serving.engine import ServingEngine
from repro.serving.sampling import SamplingParams, sample_slots


def reference_greedy_stream(cfg, params, prompt, gen, max_len=64):
    """The seed engine's per-slot path: batch=1 prefill + host decode loop."""
    cache = model_lib.init_cache(cfg, 1, max_len, jnp.dtype(cfg.dtype))
    logits, cache = model_lib.prefill(
        cfg, params, {"tokens": jnp.asarray(prompt)[None]}, cache)
    toks = [int(jnp.argmax(logits, -1)[0])]
    pos = len(prompt)
    for _ in range(gen - 1):
        tok = jnp.asarray([[toks[-1]]], jnp.int32)
        logits, cache = model_lib.decode_step(
            cfg, params, tok, jnp.asarray(pos, jnp.int32), cache)
        toks.append(int(jnp.argmax(logits, -1)[0]))
        pos += 1
    return toks


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("tinyllama-1.1b", smoke=True)
    params, _ = model_lib.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_engine_serves_all_requests(small_model):
    cfg, params = small_model
    eng = ServingEngine(cfg, params, max_batch=2, max_len=64, prompt_bucket=8)
    rng = np.random.default_rng(0)
    uids = [eng.submit(rng.integers(0, cfg.vocab_size, 5 + 3 * i),
                       SamplingParams(max_new_tokens=6)) for i in range(5)]
    finished = eng.run()
    assert sorted(r.uid for r in finished) == sorted(uids)
    assert all(len(r.output_tokens) == 6 for r in finished)
    s = eng.latency_summary()
    assert s["requests"] == 5
    assert s["ttlt_ms"] >= s["ttft_ms"] > 0


def test_engine_greedy_matches_reference_decode(small_model):
    """Tokens produced through the engine == tokens from a manual prefill +
    greedy decode loop (per-slot positions are honest)."""
    cfg, params = small_model
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    gen = 5
    ref_tokens = reference_greedy_stream(cfg, params, prompt, gen)

    eng = ServingEngine(cfg, params, max_batch=2, max_len=64, prompt_bucket=8)
    eng.submit(prompt, SamplingParams(temperature=0.0, max_new_tokens=gen))
    # a second, longer request sharing the batch must not corrupt slot 0
    eng.submit(rng.integers(0, cfg.vocab_size, 13),
               SamplingParams(temperature=0.0, max_new_tokens=gen))
    finished = eng.run()
    got = next(r for r in finished if r.uid == 0).output_tokens
    assert got == ref_tokens


def test_fused_step_matches_per_slot_reference_under_queue_pressure(small_model):
    """Three greedy requests through two slots (queue pressure: the third is
    admitted into a recycled slot) all reproduce the per-slot reference
    streams token-for-token."""
    cfg, params = small_model
    rng = np.random.default_rng(3)
    # bucket-aligned lengths so the engine's left-padded prefill sees the
    # exact same context as the unpadded reference loop
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (8, 16, 8)]
    gens = [4, 7, 5]
    refs = [reference_greedy_stream(cfg, params, p, g)
            for p, g in zip(prompts, gens)]

    eng = ServingEngine(cfg, params, max_batch=2, max_len=64, prompt_bucket=8)
    for p, g in zip(prompts, gens):
        eng.submit(p, SamplingParams(temperature=0.0, max_new_tokens=g))
    finished = eng.run()
    assert len(finished) == 3
    for uid, ref in enumerate(refs):
        got = next(r for r in finished if r.uid == uid).output_tokens
        assert got == ref, f"request {uid} diverged from reference"


def test_engine_energy_attribution_sums_to_monitor_total(small_model):
    cfg, params = small_model
    eng = ServingEngine(cfg, params, max_batch=2, max_len=64, prompt_bucket=8)
    rng = np.random.default_rng(4)
    for i in range(3):
        eng.submit(rng.integers(0, cfg.vocab_size, 5 + i),
                   SamplingParams(max_new_tokens=5))
    mon = PowerMonitor(SyntheticReader(lambda t: 50.0), interval_s=0.02)
    eng.attach_monitor(mon)
    with mon:
        finished = eng.run()
    assert len(finished) == 3
    assert all(r.joules > 0.0 for r in finished)
    total = sum(r.joules for r in finished)
    # attribution is internally exact ...
    assert total == pytest.approx(eng.attributed_joules, rel=1e-9)
    # ... and matches the monitor's measured total up to the (tiny) tail
    # between the engine's final flush and the monitor's exit
    assert total == pytest.approx(mon.result().joules, rel=0.1)
    # the summary surfaces the sampler's achieved rate and dropped reads
    # so the >= 5-10 Hz protocol requirement is checkable, not assumed
    summary = eng.latency_summary()
    assert summary["power_samples_per_sec"] > 0.0
    assert summary["power_reads_dropped"] == 0


def test_engine_stream_hook_emits_tokens_in_order(small_model):
    """The stream hook fires inside the per-step host sync: every token
    exactly once, in emission order, with one finish edge per request
    (after its joules are attributed)."""
    cfg, params = small_model
    eng = ServingEngine(cfg, params, max_batch=2, max_len=64, prompt_bucket=8)
    events = []
    eng.stream_hook = lambda uid, toks, fin: events.append((uid, toks, fin))
    rng = np.random.default_rng(6)
    uids = [eng.submit(rng.integers(0, cfg.vocab_size, 6),
                       SamplingParams(max_new_tokens=4)) for _ in range(3)]
    finished = {r.uid: r for r in eng.run()}
    streamed = {u: [] for u in uids}
    finishes = {u: 0 for u in uids}
    for uid, toks, fin in events:
        assert finishes[uid] == 0, "tokens after finish edge"
        streamed[uid].extend(toks)
        if fin:
            finishes[uid] += 1
    for u in uids:
        assert streamed[u] == list(finished[u].output_tokens)
        assert finishes[u] == 1


def test_engine_truncates_long_prompts_keeping_tail(small_model):
    cfg, params = small_model
    eng = ServingEngine(cfg, params, max_batch=1, max_len=32, prompt_bucket=8)
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab_size, 40).astype(np.int32)
    eng.submit(prompt, SamplingParams(max_new_tokens=1))
    finished = eng.run()
    assert finished[0].truncated
    assert eng.latency_summary()["truncated"] == 1
    # the kept context is the *last* max_len - 1 tokens
    ref = reference_greedy_stream(cfg, params, prompt[-31:], 1, max_len=32)
    assert finished[0].output_tokens == ref


def test_percentile_nearest_rank():
    from repro.serving.engine import _percentile

    assert _percentile([10.0, 20.0], 50) == 10.0
    assert _percentile([1, 2, 3, 4], 50) == 2
    assert _percentile([1, 2, 3, 4], 95) == 4
    # singletons at every quantile, and the empty list (an engine with no
    # completed requests) degrades to 0.0 instead of an IndexError
    for q in (0, 50, 99, 100):
        assert _percentile([5.0], q) == 5.0
    for q in (0, 50, 95, 100):
        assert _percentile([], q) == 0.0


def test_latency_summary_zero_completed_requests(small_model):
    """An engine that never completed a request reports an empty summary
    (and flush() is safe) rather than crashing on empty percentiles."""
    cfg, params = small_model
    eng = ServingEngine(cfg, params, max_batch=1, max_len=64)
    assert eng.latency_summary() == {}
    eng.flush()
    assert eng.latency_summary() == {}
    # a submitted-but-never-served request still doesn't count
    eng.submit(np.zeros(4, np.int32), SamplingParams(max_new_tokens=2))
    assert eng.latency_summary() == {}


def test_engine_clamps_top_k_consistently(small_model):
    """Requests asking for top_k beyond the fused step's static bound are
    clamped at submission, so the first (prefill) token and the decode
    stream sample from the same distribution."""
    cfg, params = small_model
    eng = ServingEngine(cfg, params, max_batch=1, max_len=64, top_k_max=16)
    rng = np.random.default_rng(6)
    eng.submit(rng.integers(0, cfg.vocab_size, 5),
               SamplingParams(temperature=1.0, top_k=1000, max_new_tokens=2))
    assert eng.queue[0].params.top_k == 16


def test_sample_slots_mixed_params():
    """Greedy slots take argmax; stochastic slots stay inside their top-k."""
    key = jax.random.PRNGKey(0)
    logits = jax.random.normal(key, (4, 64)) * 2
    temperature = jnp.asarray([0.0, 1.0, 0.0, 0.7], jnp.float32)
    top_k = jnp.asarray([0, 3, 0, 5], jnp.int32)
    for i in range(10):
        tok = sample_slots(logits, temperature, top_k,
                           jax.random.fold_in(key, i))
        argmax = np.asarray(jnp.argmax(logits, -1))
        assert int(tok[0]) == argmax[0] and int(tok[2]) == argmax[2]
        for slot in (1, 3):
            k = int(top_k[slot])
            allowed = np.asarray(jax.lax.top_k(logits[slot], k)[1])
            assert int(tok[slot]) in allowed


def test_engine_eos_stops_early(small_model):
    cfg, params = small_model
    eng = ServingEngine(cfg, params, max_batch=1, max_len=64)
    rng = np.random.default_rng(2)
    # pick the model's own first greedy token as "eos" to force a 1-token gen
    prompt = rng.integers(0, cfg.vocab_size, 6)
    eng.submit(prompt, SamplingParams(max_new_tokens=8))
    first = eng.run()[0].output_tokens[0]
    eng2 = ServingEngine(cfg, params, max_batch=1, max_len=64)
    eng2.submit(prompt, SamplingParams(max_new_tokens=8, eos_token=first))
    r = eng2.run()[0]
    assert len(r.output_tokens) == 1 and r.output_tokens[0] == first


def test_serve_driver():
    from repro.launch.serve import main

    assert main(["--arch", "qwen1.5-0.5b", "--smoke", "--requests", "3",
                 "--max-new", "4", "--max-batch", "2", "--max-len", "64",
                 "--power-reader", "synthetic"]) == 0


def test_serve_driver_open_loop(capsys):
    from repro.launch.serve import main

    assert main(["--arch", "qwen1.5-0.5b", "--smoke", "--requests", "3",
                 "--max-new", "4", "--max-batch", "2", "--max-len", "64",
                 "--arrival-rate", "8", "--power-reader", "synthetic"]) == 0
    out = capsys.readouterr().out
    assert "ttft_p99_ms" in out and "J/Req" in out
