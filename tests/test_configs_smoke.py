"""Per-architecture smoke tests (assignment requirement).

Each assigned arch instantiates its REDUCED config and runs one forward +
one train step on CPU, asserting output shapes and absence of NaNs.  Full
configs are exercised only through the dry-run (ShapeDtypeStruct, no
allocation) — see ``test_dryrun_logic`` for the cell bookkeeping.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, PAPER, get_config, list_archs
from repro.data.synthetic import batch_for_model
from repro.models import model as model_lib
from repro.training import step as step_lib
from repro.training.optimizer import AdamW, constant_schedule


def _batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    tok_len = S - cfg.num_vision_tokens if cfg.num_vision_tokens else S
    if cfg.is_encdec:
        tok_len = S // 2
    data = {
        "tokens": rng.integers(0, cfg.vocab_size, (B, tok_len)).astype(np.int32),
        "labels": rng.integers(0, cfg.vocab_size, (B, tok_len)).astype(np.int32),
    }
    return {k: jnp.asarray(v) for k, v in batch_for_model(cfg, data, rng).items()}


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_forward(arch):
    cfg = get_config(arch, smoke=True)
    params, axes = model_lib.init(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits = model_lib.forward_train(cfg, params, batch, remat=False)
    B = batch["tokens"].shape[0]
    S_expected = batch["tokens"].shape[1] + cfg.num_vision_tokens
    assert logits.shape == (B, S_expected, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any()), f"{arch}: NaN logits"


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_train_step(arch):
    cfg = get_config(arch, smoke=True)
    opt = AdamW(schedule=constant_schedule(1e-3))
    state, _ = step_lib.init_state(cfg, opt, jax.random.PRNGKey(0))
    step = jax.jit(step_lib.make_train_step(cfg, opt, remat=True))
    batch = _batch(cfg)
    state, metrics = step(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), f"{arch}: non-finite loss {loss}"
    assert loss > 0
    assert np.isfinite(float(metrics["grad_norm"]))
    # one more step must change the loss (params actually updated)
    _, metrics2 = step(state, batch)
    assert float(metrics2["loss"]) != loss


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_decode_matches_forward(arch):
    cfg = get_config(arch, smoke=True)
    if cfg.is_moe:
        cfg = cfg.replace(moe_capacity_factor=8.0)  # no token drops
    params, _ = model_lib.init(cfg, jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = _batch(cfg, B=B, S=S, seed=1)
    full = model_lib.forward_train(cfg, params, batch, remat=False)
    cache = model_lib.init_cache(cfg, B, S + 2, jnp.float32)
    pre = dict(batch)
    pre.pop("labels")
    pre["tokens"] = batch["tokens"][:, :-1]
    logits_pre, cache = model_lib.prefill(cfg, params, pre, cache)
    np.testing.assert_allclose(
        np.asarray(logits_pre), np.asarray(full[:, -2]), rtol=2e-4, atol=2e-4)
    pos = jnp.asarray(batch["tokens"].shape[1] - 1 + cfg.num_vision_tokens, jnp.int32)
    logits_dec, _ = model_lib.decode_step(
        cfg, params, batch["tokens"][:, -1:], pos, cache)
    np.testing.assert_allclose(
        np.asarray(logits_dec), np.asarray(full[:, -1]), rtol=2e-3, atol=2e-3)


def test_full_configs_validate():
    """The FULL configs are structurally valid (no allocation)."""
    for arch in list_archs():
        cfg = get_config(arch)
        cfg.validate()
        shapes, axes = model_lib.param_axes(cfg)
        n = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))
        assert n > 1e8, f"{arch}: suspiciously few params {n}"


def test_assigned_pool_complete():
    assert len(ASSIGNED) == 10
    assert set(ASSIGNED) == {
        "minitron-4b", "tinyllama-1.1b", "qwen1.5-0.5b", "command-r-plus-104b",
        "llava-next-34b", "seamless-m4t-large-v2", "moonshot-v1-16b-a3b",
        "qwen3-moe-30b-a3b", "xlstm-1.3b", "recurrentgemma-2b",
    }
    assert "llama3.1-8b" in PAPER and "nemotron-h-8b" in PAPER
