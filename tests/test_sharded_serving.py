"""Tensor-parallel sharded serving equivalence suite.

The contract under test: sharding heads/FFN over a ``(tp,)`` mesh inside
the fused engine step changes *where* the math runs, never *what tokens
come out*.  On a forced multi-device CPU host
(``XLA_FLAGS=--xla_force_host_platform_device_count=4``; the equivalence
tests skip without it), greedy AND sampled streams at ``tp=2`` and
``tp=4`` must be byte-identical to the single-device engine across
{contiguous, paged} x {chunked, unchunked} x {preemption on/off} x
{speculative on/off}, with the ≤ 2 dispatches/step bound intact.  The
per-device ledgers ride along: per-device KV bytes sum to the aggregate
when heads shard evenly, per-device block accounting partitions each
shard, and per-device joules tile exactly to the run total — including
when one device's power reader drops every read.
"""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.energy import (DeviceMonitorGroup, PowerReader,
                               SyntheticReader)
from repro.launch.mesh import make_tp_mesh
from repro.models import model as model_lib
from repro.serving.engine import ServingEngine
from repro.serving.sampling import SamplingParams
from repro.serving.workload import LengthDist, WorkloadSpec, poisson_trace

pytestmark = pytest.mark.sharded

multidevice = pytest.mark.skipif(
    len(jax.devices()) < 4,
    reason="needs a forced multi-device host: "
           "XLA_FLAGS=--xla_force_host_platform_device_count=4")


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("qwen1.5-0.5b", smoke=True)
    params, axes = model_lib.init(cfg, jax.random.PRNGKey(0))
    return cfg, params, axes


def _arrivals(cfg, n=6, temperature=0.0, seed=2):
    spec = WorkloadSpec(
        arrival_rate=0.0, num_requests=n,
        prompt_len=LengthDist(kind="lognormal", mean=16.0, low=2, high=48),
        output_len=LengthDist(kind="uniform", low=2, high=9),
        temperature=temperature, top_k=8, seed=seed,
    )
    return poisson_trace(spec, cfg.vocab_size)


def _engine(cfg, params, axes, tp, **kw):
    mesh = make_tp_mesh(tp) if tp > 1 else None
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_len", 64)
    kw.setdefault("prompt_bucket", 8)
    return ServingEngine(cfg, params, mesh=mesh,
                         param_axes=axes if mesh is not None else None, **kw)


def _streams(cfg, params, axes, arrivals, tp, **kw):
    eng = _engine(cfg, params, axes, tp, **kw)
    for a in arrivals:
        eng.submit(a.prompt, a.params)
    finished = eng.run()
    return eng, {r.uid: list(r.output_tokens) for r in finished}


# -- the equivalence matrix ---------------------------------------------------

@multidevice
@pytest.mark.parametrize("temperature", [0.0, 0.7])
@pytest.mark.parametrize("layout,chunk,spec", [
    ("contiguous", 0, "off"),
    ("contiguous", 8, "off"),
    ("contiguous", 0, "lookup"),
    ("contiguous", 8, "lookup"),
    ("paged", 0, "off"),
    ("paged", 8, "off"),
    ("paged", 0, "lookup"),
    ("paged", 8, "lookup"),
])
def test_tp_stream_equivalence(small_model, layout, chunk, spec, temperature):
    """tp=2 and tp=4 streams byte-identical to tp=1 for every layout x
    chunking x speculation combination, greedy and sampled."""
    cfg, params, axes = small_model
    arrivals = _arrivals(cfg, temperature=temperature)
    kw = dict(cache_layout=layout, prefill_chunk=chunk, speculative=spec)
    _, base = _streams(cfg, params, axes, arrivals, 1, **kw)
    assert len(base) == len(arrivals)
    for tp in (2, 4):
        _, got = _streams(cfg, params, axes, arrivals, tp, **kw)
        assert got == base, (tp, layout, chunk, spec, temperature)


@multidevice
@pytest.mark.parametrize("spec", ["off", "lookup"])
def test_tp_preemption_equivalence(small_model, spec):
    """An overcommitted pool preempts and recomputes identically under a
    sharded engine: streams match the uncontended single-device run, and
    preemptions actually fire on every tp setting."""
    cfg, params, axes = small_model
    rng = np.random.default_rng(1)
    prompts = [rng.integers(1, cfg.vocab_size, size=int(rng.integers(10, 25)))
               for _ in range(8)]

    def run(tp, **kw):
        eng = _engine(cfg, params, axes, tp, max_batch=3, seed=3,
                      cache_layout="paged", prefill_chunk=4, kv_block_size=8,
                      speculative=spec, **kw)
        for p in prompts:
            eng.submit(p, SamplingParams(max_new_tokens=10, temperature=0.8))
        return {r.uid: list(r.output_tokens) for r in eng.run()}, eng

    base, _ = run(1)
    for tp in (2, 4):
        got, eng = run(tp, preemption="recompute", kv_num_blocks=10)
        assert got == base, (tp, spec)
        assert eng.preemptions > 0, "pool never ran dry: test lost its teeth"


@multidevice
def test_tp_prefix_cache_equivalence(small_model):
    """Prefix-cached admissions reuse the same sharded pool blocks: warm
    streams match tp=1, and blocks are actually reused."""
    cfg, params, axes = small_model
    shared = np.arange(1, 17)
    prompts = [np.concatenate([shared, [60 + i, 70 + i]]) for i in range(4)]

    def run(tp):
        eng = _engine(cfg, params, axes, tp, cache_layout="paged",
                      prefill_chunk=4, kv_block_size=4, prefix_cache=True)
        for p in prompts:
            eng.submit(p, SamplingParams(max_new_tokens=4, temperature=0.7))
        return {r.uid: list(r.output_tokens) for r in eng.run()}, eng

    base, _ = run(1)
    for tp in (2, 4):
        got, eng = run(tp)
        assert got == base, tp
        assert eng.latency_summary()["prefix_blocks_reused"] > 0


@multidevice
def test_tp_dispatch_bound(small_model):
    """Sharding does not break the unified-step economics: a chunked
    non-preemptive sharded engine stays at <= 2 dispatches per step."""
    cfg, params, axes = small_model
    arrivals = _arrivals(cfg, n=8, temperature=0.7, seed=9)
    for tp in (2, 4):
        for layout in ("contiguous", "paged"):
            eng, _ = _streams(cfg, params, axes, arrivals, tp,
                              cache_layout=layout, prefill_chunk=4,
                              prefill_budget=12)
            assert eng._dispatch_samples, "no steps recorded"
            assert max(eng._dispatch_samples) <= 2, (
                tp, layout, eng._dispatch_samples)


# -- per-device ledgers -------------------------------------------------------

@multidevice
def test_tp_kv_bytes_by_device_sum_to_aggregate(small_model):
    """Heads divide evenly on the smoke config, so each device holds an
    equal KV shard and the per-device bytes sum exactly to the aggregate;
    per-device block accounting partitions every shard identically."""
    cfg, params, axes = small_model
    arrivals = _arrivals(cfg, n=4)
    for tp in (2, 4):
        eng, _ = _streams(cfg, params, axes, arrivals, tp,
                          cache_layout="paged", prefill_chunk=8)
        per = eng.kv_bytes_by_device(peak=True)
        assert len(per) == tp
        assert sum(per) == eng.kv_bytes_in_use(peak=True)
        assert len(set(per)) == 1, per  # 4 kv heads shard evenly
        for view in eng.pool_accounting_by_device():
            assert (view["free"] + view["in_use"] + view["evictable"]
                    == view["allocatable"])
            assert view["in_use"] == eng._pool.in_use
        s = eng.latency_summary()
        assert s["tp_devices"] == tp
        assert s["kv_bytes_peak_per_device"] == per
        assert s["pool_blocks_in_use_per_device"] == [0] * tp  # drained

    # contiguous: per-device stripes of the worst-case reservation
    eng, _ = _streams(cfg, params, axes, _arrivals(cfg, n=3), 2,
                      cache_layout="contiguous")
    per = eng.kv_bytes_by_device()
    assert sum(per) == eng.kv_bytes_worst_case


class _DeadReader(PowerReader):
    """Every read raises — a device whose power sensor is offline."""

    def read_watts(self):
        raise RuntimeError("sensor offline")


def _run_with_monitor(cfg, params, axes, monitor, expect_warning):
    eng = _engine(cfg, params, axes, 1, monitor=monitor,
                  cache_layout="paged", prefill_chunk=8)
    rng = np.random.default_rng(0)
    for _ in range(3):
        eng.submit(rng.integers(1, cfg.vocab_size, 12),
                   SamplingParams(max_new_tokens=6))
    if expect_warning:
        with pytest.warns(RuntimeWarning, match="dropped"):
            with monitor:
                eng.run()
    else:
        with monitor:
            eng.run()
    return eng


def test_tp_per_device_joules_tile_to_total(small_model):
    """The per-device ledger keys: each device's windowed integral over
    the group window, summing exactly to ``result().joules`` (same
    step-function ledger, grouped per device).  Needs no mesh — the
    monitor group is pure host-side instrumentation."""
    cfg, params, axes = small_model
    group = DeviceMonitorGroup(
        [SyntheticReader(lambda t, w=20.0 + 10.0 * i: w) for i in range(4)],
        interval_s=0.01)
    eng = _run_with_monitor(cfg, params, axes, group, expect_warning=False)
    s = eng.latency_summary()
    total = group.result().joules
    assert len(s["joules_per_device"]) == 4
    assert sum(s["joules_per_device"]) == pytest.approx(
        total, rel=1e-9, abs=1e-12)
    assert all(j > 0.0 for j in s["joules_per_device"])
    # request-windowed tilings per device sum to the aggregate windows
    t0, t1 = group.window
    mid = (t0 + t1) / 2.0
    tiled = (sum(group.joules_between_by_device(t0, mid))
             + sum(group.joules_between_by_device(mid, t1)))
    assert tiled == pytest.approx(total, rel=1e-9, abs=1e-12)


def test_tp_summary_survives_dead_device(small_model):
    """Satellite regression: one device dropping every power read must
    degrade the summary gracefully — 0.0 J for that device, its drops
    counted in ``power_reads_dropped``, no zero-division, and the live
    devices' tiling still exact."""
    cfg, params, axes = small_model
    group = DeviceMonitorGroup(
        [SyntheticReader(lambda t: 25.0), _DeadReader()], interval_s=0.01)
    eng = _run_with_monitor(cfg, params, axes, group, expect_warning=True)
    s = eng.latency_summary()
    assert s["power_reads_dropped"] >= 1
    assert s["power_reads_dropped_per_device"][1] == s["power_reads_dropped"]
    assert s["joules_per_device"][1] == 0.0
    assert s["joules_per_device"][0] > 0.0
    assert s["power_samples_per_sec_per_device"][1] == 0.0
    assert sum(s["joules_per_device"]) == pytest.approx(
        group.result().joules, rel=1e-9, abs=1e-12)
    assert s["joules_total"] >= 0.0


def test_tp_all_devices_dead_summary_does_not_crash(small_model):
    """Even a group whose every reader fails yields a well-formed summary:
    zero joules, all drops counted — mirroring the single-monitor
    power_reads_dropped handling."""
    cfg, params, axes = small_model
    group = DeviceMonitorGroup([_DeadReader(), _DeadReader()],
                               interval_s=0.01)
    eng = _run_with_monitor(cfg, params, axes, group, expect_warning=True)
    s = eng.latency_summary()
    assert s["joules_total"] == 0.0
    assert s["joules_per_token"] == 0.0
    assert s["joules_per_device"] == [0.0, 0.0]
    assert s["power_reads_dropped"] >= 2
