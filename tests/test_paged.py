"""Paged KV cache: paged-vs-contiguous token equivalence (greedy and
sampled, mixed-length Poisson workloads, sliding-window interaction),
block free/reuse after finish, pool-exhaustion admission backpressure,
and batched multi-slot admission."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as model_lib
from repro.models.config import ModelConfig
from repro.serving.engine import Request, ServingEngine
from repro.serving.sampling import SamplingParams
from repro.serving.workload import LengthDist, WorkloadSpec, poisson_trace


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("tinyllama-1.1b", smoke=True)
    params, _ = model_lib.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def hybrid_model():
    """Tiny stack mixing full attention with sliding-window layers."""
    cfg = ModelConfig(
        name="toy-hybrid", num_layers=4, d_model=64, num_heads=4,
        num_kv_heads=2, d_ff=128, vocab_size=256,
        block_pattern=("attn", "local_attn"), sliding_window=12,
        dtype="float32", param_dtype="float32",
    ).validate()
    params, _ = model_lib.init(cfg, jax.random.PRNGKey(1))
    return cfg, params


def _run_engine(cfg, params, arrivals, layout, **kw):
    eng = ServingEngine(cfg, params, max_batch=2, max_len=64,
                        prompt_bucket=8, cache_layout=layout, **kw)
    for a in arrivals:
        eng.submit(a.prompt, a.params)
    finished = eng.run()
    return eng, {r.uid: list(r.output_tokens) for r in finished}


def _poisson_arrivals(cfg, n=6, temperature=0.7, seed=2):
    spec = WorkloadSpec(
        arrival_rate=0.0, num_requests=n,
        prompt_len=LengthDist(kind="lognormal", mean=16.0, low=2, high=48),
        output_len=LengthDist(kind="uniform", low=2, high=9),
        temperature=temperature, top_k=8, seed=seed,
    )
    return poisson_trace(spec, cfg.vocab_size)


@pytest.mark.parametrize("temperature", [0.0, 0.7])
def test_paged_matches_contiguous_mixed_length_poisson(small_model, temperature):
    """Identical token streams across layouts for the same seed/config,
    under a mixed-length Poisson-sampled workload with queue pressure."""
    cfg, params = small_model
    arrivals = _poisson_arrivals(cfg, temperature=temperature)
    _, out_c = _run_engine(cfg, params, arrivals, "contiguous")
    eng_p, out_p = _run_engine(cfg, params, arrivals, "paged")
    assert set(out_c) == set(out_p) and len(out_c) == len(arrivals)
    for uid in out_c:
        assert out_c[uid] == out_p[uid], f"request {uid} diverged"
    assert eng_p.blocks_in_use == 0  # everything returned at drain


def test_paged_matches_contiguous_with_sliding_window(hybrid_model):
    """local_attn layers keep their ring buffers under the paged layout;
    mixed attn/local_attn stacks stay stream-identical across layouts."""
    cfg, params = hybrid_model
    arrivals = _poisson_arrivals(cfg, n=5, temperature=0.0, seed=7)
    _, out_c = _run_engine(cfg, params, arrivals, "contiguous")
    _, out_p = _run_engine(cfg, params, arrivals, "paged")
    assert out_c == out_p and len(out_c) == 5


def test_blocks_freed_and_reused_after_finish(small_model):
    cfg, params = small_model
    eng = ServingEngine(cfg, params, max_batch=2, max_len=64,
                        prompt_bucket=8, cache_layout="paged",
                        kv_block_size=16)
    total_free = len(eng._free_blocks)
    rng = np.random.default_rng(0)
    for i in range(5):
        eng.submit(rng.integers(0, cfg.vocab_size, 8),
                   SamplingParams(max_new_tokens=4))
    finished = eng.run()
    assert len(finished) == 5
    # every block came back to the free stack ...
    assert eng.blocks_in_use == 0
    assert len(eng._free_blocks) == total_free
    assert all(not b for b in eng._slot_blocks)
    # ... and 5 requests through 2 slots can only fit by reusing blocks:
    # each needs 1 block (8 prompt + 4 new <= 16), peak is bounded by slots
    assert 1 <= eng.peak_blocks_in_use <= 2
    # freed slots point their table rows back at the garbage block
    assert int(jnp.sum(eng._state["block_tables"])) == 0


def test_pool_exhaustion_backpressure(small_model):
    """A pool that fits one worst-case request at a time forces queueing,
    but every request still completes with the right output length."""
    cfg, params = small_model
    blocks_per_req = 64 // 16
    eng = ServingEngine(cfg, params, max_batch=2, max_len=64,
                        prompt_bucket=8, cache_layout="paged",
                        kv_block_size=16, kv_num_blocks=1 + blocks_per_req)
    rng = np.random.default_rng(1)
    for i in range(3):
        # max_new=60 books the full 64-token budget -> 4 blocks each
        eng.submit(rng.integers(0, cfg.vocab_size, 8),
                   SamplingParams(max_new_tokens=60))
    eng.step()  # first admit: exactly one request fits the pool
    assert sum(s is not None for s in eng.slots) == 1
    assert len(eng.queue) == 2
    assert eng.blocks_in_use == blocks_per_req
    finished = eng.run()
    assert len(finished) == 3
    assert all(len(r.output_tokens) > 0 for r in finished)
    assert eng.peak_blocks_in_use == blocks_per_req  # never over-admitted
    assert eng.blocks_in_use == 0


def test_pool_too_small_for_one_request_rejected(small_model):
    cfg, params = small_model
    # the error is actionable: it names the flag and the computed minimum
    with pytest.raises(ValueError, match=r"--kv-num-blocks.*>= 5"):
        ServingEngine(cfg, params, max_batch=2, max_len=64,
                      cache_layout="paged", kv_block_size=16,
                      kv_num_blocks=2)


def test_batched_admission_single_prefill_per_bucket(small_model):
    """Requests sharing a prompt bucket are prefilled in one batched call."""
    cfg, params = small_model
    eng = ServingEngine(cfg, params, max_batch=4, max_len=64, prompt_bucket=8)
    shapes = []
    orig = eng._prefill
    eng._prefill = lambda p, b: (shapes.append(tuple(b["tokens"].shape)),
                                 orig(p, b))[1]
    rng = np.random.default_rng(4)
    for _ in range(3):
        eng.submit(rng.integers(0, cfg.vocab_size, 6),
                   SamplingParams(max_new_tokens=3))
    eng.step()
    assert shapes == [(3, 8)]  # one prefill, batch=3, bucketed plen=8
    finished = eng.run()
    assert len(finished) == 3


def test_request_params_default_not_shared():
    """dataclass default_factory: each Request gets its own SamplingParams."""
    a = Request(uid=0, prompt=np.zeros(1, np.int32))
    b = Request(uid=1, prompt=np.zeros(1, np.int32))
    assert a.params is not b.params
    assert dataclasses.fields(Request)[2].default is dataclasses.MISSING


def test_paged_cache_size_reporting():
    """core.cache classifies pool leaves as kv and the paged analytic
    undercuts the contiguous worst case for short-heavy lengths."""
    from repro.core.cache import analytic_kv_bytes, paged_kv_bytes, profile_cache

    cfg = get_config("tinyllama-1.1b", smoke=True)
    rep = profile_cache(cfg, 4, 128, layout="paged", block_size=16)
    assert rep.kv_bytes > 0
    # worst-case pool ~= contiguous worst case (+1 garbage block per layer)
    contig = profile_cache(cfg, 4, 128)
    assert rep.kv_bytes >= contig.kv_bytes
    lengths = [24, 16, 40, 8]
    paged = paged_kv_bytes(cfg, lengths, 16)
    worst = analytic_kv_bytes(cfg, len(lengths), 128)
    assert paged * 2 <= worst
