"""OpenAI-compatible HTTP server: SSE streaming order, client-vs-engine
timestamps, metrics surface, and the steady-state loadgen energy ledger."""

import asyncio
import json
import math
import time

import jax
import numpy as np
import pytest

aiohttp = pytest.importorskip("aiohttp")

from repro.core.energy import PowerMonitor, SyntheticReader  # noqa: E402
from repro.models import model as model_lib  # noqa: E402
from repro.models.config import ModelConfig  # noqa: E402
from repro.serving.client import fetch_metrics, stream_completion  # noqa: E402
from repro.serving.engine import ServingEngine  # noqa: E402
from repro.serving.loadgen import (LoadSpec, attribute_energy,  # noqa: E402
                                   prewarm_engine, run_load)
from repro.serving.sampling import SamplingParams  # noqa: E402
from repro.serving.server import encode_prompt, start_http_server  # noqa: E402

pytestmark = pytest.mark.server


def _tiny_cfg():
    return ModelConfig(
        name="srv", num_layers=2, d_model=32, num_heads=2,
        num_kv_heads=2, d_ff=64, vocab_size=128,
        dtype="float32", param_dtype="float32",
    ).validate()


@pytest.fixture(scope="module")
def server():
    """One server over a prewarmed tiny engine, shared across tests."""
    cfg = _tiny_cfg()
    params, _ = model_lib.init(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, max_batch=2, max_len=64)
    prewarm_engine(engine, prompt_len=8, concurrency=2,
                   vocab_size=cfg.vocab_size)
    handle = start_http_server(engine, model_name=cfg.name)
    yield handle, cfg, params
    handle.close()


async def _collect_sse(url, payload):
    """Raw SSE chunk stream with per-chunk arrival timestamps."""
    events = []
    async with aiohttp.ClientSession() as session:
        async with session.post(f"{url}/v1/completions", json=payload) as r:
            assert r.status == 200
            assert r.headers["Content-Type"].startswith("text/event-stream")
            async for raw in r.content:
                line = raw.strip()
                if not line.startswith(b"data:"):
                    continue
                data = line[5:].strip()
                if data == b"[DONE]":
                    events.append((time.perf_counter(), "[DONE]"))
                    break
                events.append((time.perf_counter(), json.loads(data)))
    return events


def test_stream_order_and_timestamps(server):
    handle, cfg, _ = server
    send = time.perf_counter()
    events = asyncio.run(_collect_sse(handle.url, {
        "prompt": [1, 2, 3, 4, 5], "max_tokens": 6, "stream": True}))
    # terminal sentinel, exactly once, last
    assert [e for _, e in events].count("[DONE]") == 1
    assert events[-1][1] == "[DONE]"
    chunks = [e for _, e in events[:-1]]
    token_chunks = [c for c in chunks if c["choices"][0]["finish_reason"] is None]
    final = chunks[-1]
    # token chunks are contiguous and in order; the final chunk closes
    streamed = []
    for c in token_chunks:
        assert c["elana"]["first_index"] == len(streamed)
        streamed.extend(c["elana"]["tokens"])
    assert len(streamed) == 6
    assert final["choices"][0]["finish_reason"] == "length"
    assert final["usage"] == {"prompt_tokens": 5, "completion_tokens": 6,
                              "total_tokens": 11}
    # engine-side stamps ride the final chunk and order correctly against
    # the client's own clock (same CLOCK_MONOTONIC domain)
    ext = final["elana"]
    assert send < ext["engine_submit_s"] <= ext["engine_first_token_s"]
    assert ext["engine_first_token_s"] <= ext["engine_finish_s"]
    first_arrival = events[0][0]
    assert ext["engine_first_token_s"] <= first_arrival
    # arrivals are monotonic and every emit stamp precedes its arrival
    arrivals = [t for t, c in events[:-1]]
    assert arrivals == sorted(arrivals)
    for (arrival, c) in events[:-1]:
        if isinstance(c, dict) and c["choices"][0]["finish_reason"] is None:
            assert c["elana"]["emit_s"] <= arrival


def test_stream_matches_direct_engine(server):
    """Greedy decoding through HTTP is byte-identical to driving a fresh
    engine directly with the same prompt."""
    handle, cfg, params = server
    prompt = [7, 11, 13, 17, 19, 23, 29, 31]

    async def go():
        async with aiohttp.ClientSession() as s:
            return await stream_completion(s, handle.url, prompt,
                                           max_tokens=8)

    rec = asyncio.run(go())
    assert not rec.error
    ref = ServingEngine(cfg, params, max_batch=2, max_len=64)
    ref.submit(np.asarray(prompt, np.int32),
               SamplingParams(max_new_tokens=8))
    done = ref.run()
    assert rec.tokens == list(done[0].output_tokens)


def test_client_record_latency_ordering(server):
    handle, _, _ = server

    async def go():
        async with aiohttp.ClientSession() as s:
            return await stream_completion(s, handle.url, [3, 1, 4, 1, 5],
                                           max_tokens=5)

    rec = asyncio.run(go())
    assert not rec.error
    assert rec.finish_reason == "length"
    assert len(rec.tokens) == 5
    assert rec.send_time < rec.first_chunk_time <= rec.last_chunk_time
    # client-observed latencies bound the engine's own from above
    assert rec.client_ttft_s >= rec.engine_ttft_s > 0.0
    assert rec.client_ttlt_s >= rec.client_ttft_s
    assert rec.usage["completion_tokens"] == 5


def test_non_streaming_completion(server):
    handle, _, _ = server

    async def go():
        async with aiohttp.ClientSession() as s:
            async with s.post(f"{handle.url}/v1/completions", json={
                    "prompt": [1, 2, 3], "max_tokens": 4}) as r:
                assert r.status == 200
                return await r.json()

    body = asyncio.run(go())
    assert body["object"] == "text_completion"
    assert body["choices"][0]["finish_reason"] == "length"
    assert body["usage"]["completion_tokens"] == 4
    assert len(body["elana"]["tokens"]) == 4


def test_bad_requests_rejected(server):
    handle, _, _ = server

    async def go():
        out = []
        async with aiohttp.ClientSession() as s:
            for payload in ({"prompt": [], "max_tokens": 4},
                            {"prompt": [999999], "max_tokens": 4},
                            {"prompt": [1, 2], "max_tokens": 0}):
                async with s.post(f"{handle.url}/v1/completions",
                                  json=payload) as r:
                    out.append((r.status, await r.json()))
        return out

    for status, body in asyncio.run(go()):
        assert status == 400
        assert "error" in body


def test_models_and_metrics_endpoints(server):
    handle, cfg, _ = server

    async def go():
        async with aiohttp.ClientSession() as s:
            await stream_completion(s, handle.url, [2, 4, 6], max_tokens=3)
            async with s.get(f"{handle.url}/v1/models") as r:
                models = await r.json()
            return models, await fetch_metrics(s, handle.url)

    models, metrics = asyncio.run(go())
    assert [m["id"] for m in models["data"]] == [cfg.name]
    # engine ledger + server counters in one scrape
    assert metrics["requests"] >= 1
    for key in ("ttft_ms", "tpot_ms", "ttlt_ms", "tokens_per_sec",
                "server_requests_received", "server_chunks_streamed",
                "server_in_flight", "server_uptime_s"):
        assert key in metrics, key
    assert metrics["server_requests_received"] >= 1
    assert metrics["server_chunks_streamed"] >= 3


def test_concurrent_streams_complete(server):
    handle, _, _ = server

    async def go():
        async with aiohttp.ClientSession() as s:
            return await asyncio.gather(*[
                stream_completion(s, handle.url, [i + 1, i + 2, i + 3],
                                  max_tokens=4)
                for i in range(4)])

    recs = asyncio.run(go())
    assert all(not r.error for r in recs)
    assert all(len(r.tokens) == 4 for r in recs)
    assert all(r.client_ttft_s >= r.engine_ttft_s for r in recs)


def test_encode_prompt():
    assert encode_prompt([1, 2, 3], 128).tolist() == [1, 2, 3]
    assert encode_prompt("AB", 128).tolist() == [65, 66]
    with pytest.raises(ValueError):
        encode_prompt([128], 128)
    with pytest.raises(ValueError):
        encode_prompt([], 128)


def test_loadgen_steady_state_energy_ledger(server):
    """The ISSUE acceptance criterion: over a warmup-excluded steady-state
    window, client and engine latencies agree within tolerance AND the sum
    of per-request ``joules_between`` windows equals the monitor's run
    total (exact under the step-function model)."""
    handle, cfg, _ = server
    mon = PowerMonitor(
        SyntheticReader(lambda t: 40.0 + 10.0 * math.sin(t * 7.0)),
        interval_s=0.02)
    handle.server.engine.attach_monitor(mon)
    spec = LoadSpec(mode="closed", concurrency=2, warmup_s=0.4,
                    duration_s=1.2, prompt_len=8, max_new=6,
                    vocab_size=cfg.vocab_size)
    res = run_load(handle.url, spec, monitor=mon)
    s = res.summary
    assert s["steady_requests"] >= 2
    assert s["errors"] == 0
    # ledger exactness: tiles reproduce the total
    assert s["joules_attributed"] == pytest.approx(
        s["joules_total"], rel=1e-9, abs=1e-9)
    assert sum(r.joules for r in res.records) == pytest.approx(
        s["joules_total"], rel=1e-9)
    # re-tiling after the fact agrees too (attribution is deterministic)
    assert attribute_energy(res.records, mon) == pytest.approx(
        s["joules_total"], rel=1e-9)
    # client and engine views of the same requests agree within tolerance
    assert -1.0 <= s["ttft_client_minus_engine_ms"] <= 250.0
    assert abs(s["tpot_client_minus_engine_ms"]) <= 50.0
    # the protocol's sample-rate floor is verifiable from the summary
    assert s["power_samples_per_sec"] >= 0.5 / 0.02
    assert s["power_reads_dropped"] == 0
    # every steady record carries the engine's stamps
    assert all(r.engine for r in res.records)


def test_loadgen_open_loop(server):
    handle, cfg, _ = server
    spec = LoadSpec(mode="open", qps=6.0, warmup_s=0.3, duration_s=1.0,
                    prompt_len=8, max_new=4, vocab_size=cfg.vocab_size)
    res = run_load(handle.url, spec)
    s = res.summary
    assert s["steady_requests"] >= 1
    assert s["errors"] == 0
    # open loop: arrivals are schedule-driven, so the achieved rate stays
    # in the neighbourhood of the target even as completions vary
    assert 0.5 <= s["achieved_qps"] <= 12.0
