"""Traffic generation: trace determinism, replay mode, open-loop driving,
and benchmark-harness key validation."""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as model_lib
from repro.serving.engine import ServingEngine
from repro.serving.workload import (LengthDist, OpenLoopDriver, WorkloadSpec,
                                    poisson_trace, replay_trace)


def _traces_equal(a, b):
    return (len(a) == len(b)
            and all(x.time_s == y.time_s
                    and np.array_equal(x.prompt, y.prompt)
                    and x.params == y.params for x, y in zip(a, b)))


def test_poisson_trace_deterministic_per_seed():
    spec = WorkloadSpec(arrival_rate=4.0, num_requests=16, seed=7)
    t1, t2 = poisson_trace(spec, 256), poisson_trace(spec, 256)
    assert _traces_equal(t1, t2)
    t3 = poisson_trace(WorkloadSpec(arrival_rate=4.0, num_requests=16, seed=8), 256)
    assert not _traces_equal(t1, t3)
    # arrival times are non-decreasing and roughly rate-scaled
    times = [a.time_s for a in t1]
    assert times == sorted(times)
    assert 0.5 < times[-1] < 30.0


def test_length_dists():
    rng = np.random.default_rng(0)
    assert LengthDist(kind="fixed", mean=12).sample(rng) == 12
    u = [LengthDist(kind="uniform", low=3, high=9).sample(rng) for _ in range(50)]
    assert all(3 <= n <= 9 for n in u)
    ln = [LengthDist(kind="lognormal", mean=32, low=1, high=512).sample(rng)
          for _ in range(200)]
    assert 16 < np.mean(ln) < 64
    with pytest.raises(ValueError):
        LengthDist(kind="zipf").sample(rng)


def test_replay_trace_deterministic():
    sched = [(0.0, 5, 4), (0.1, 9, 6), (0.25, 7, 2)]
    a, b = replay_trace(sched, 256), replay_trace(sched, 256)
    assert _traces_equal(a, b)
    assert [x.time_s for x in a] == [0.0, 0.1, 0.25]
    assert [len(x.prompt) for x in a] == [5, 9, 7]
    assert [x.params.max_new_tokens for x in a] == [4, 6, 2]


def test_open_loop_driver_serves_trace():
    import time

    cfg = get_config("qwen1.5-0.5b", smoke=True)
    params, _ = model_lib.init(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, max_batch=2, max_len=64, prompt_bucket=8)
    schedule = [(0.0, 5, 3), (0.3, 8, 4), (0.6, 6, 3)]
    arrivals = replay_trace(schedule, cfg.vocab_size)
    t0 = time.perf_counter()
    finished = OpenLoopDriver(eng, arrivals).run()
    assert sorted(len(r.output_tokens) for r in finished) == [3, 3, 4]
    # open-loop: request i (uid == submission order) cannot have been
    # submitted before its scheduled arrival time
    for r in finished:
        assert r.submit_time - t0 >= schedule[r.uid][0] - 1e-6


def test_benchmark_run_rejects_unknown_keys(capsys):
    from benchmarks import run as bench_run

    with pytest.raises(SystemExit) as e:
        bench_run.main(["--only", "tabel2,nope"])
    assert e.value.code == 2
    err = capsys.readouterr().err
    assert "unknown module key" in err and "table2" in err
