"""Unified mixed prefill/decode step suite.

The contract under test: fusing the packed chunked-prefill frontier and
the decode+sample step into ONE device dispatch per engine step changes
*how many launches* a step costs, never *what tokens come out*.  For
{contiguous, paged} x {greedy, sampled} x {chunked, unchunked-budget} x
preemption on/off, the unified engine must emit token streams
byte-identical to the per-chunk dispatch path for the same seed.  The
dispatch economics ride along: a chunked unified engine without
preemption never exceeds two dispatches per step (the fused step plus at
most one batched admission row-reset), asserted both on curated traces
and as a hypothesis invariant over random Poisson workloads.
"""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as model_lib
from repro.serving.engine import ServingEngine
from repro.serving.sampling import SamplingParams
from repro.serving.workload import LengthDist, WorkloadSpec, poisson_trace

pytestmark = pytest.mark.chunked


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("qwen1.5-0.5b", smoke=True)
    params, _ = model_lib.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _arrivals(cfg, n=6, temperature=0.0, seed=2):
    spec = WorkloadSpec(
        arrival_rate=0.0, num_requests=n,
        prompt_len=LengthDist(kind="lognormal", mean=16.0, low=2, high=48),
        output_len=LengthDist(kind="uniform", low=2, high=9),
        temperature=temperature, top_k=8, seed=seed,
    )
    return poisson_trace(spec, cfg.vocab_size)


def _streams(cfg, params, arrivals, layout, chunk, unified, **kw):
    eng = ServingEngine(cfg, params, max_batch=2, max_len=64,
                        prompt_bucket=8, cache_layout=layout,
                        prefill_chunk=chunk, unified_step=unified, **kw)
    for a in arrivals:
        eng.submit(a.prompt, a.params)
    finished = eng.run()
    return eng, {r.uid: list(r.output_tokens) for r in finished}


@pytest.mark.parametrize("temperature", [0.0, 0.7])
@pytest.mark.parametrize("layout", ["contiguous", "paged"])
@pytest.mark.parametrize("chunk,budget", [(8, 0), (4, 16)])
def test_unified_matches_per_chunk(small_model, layout, temperature,
                                   chunk, budget):
    """Unified-step streams == per-chunk-dispatch streams, both layouts,
    greedy and sampled, single-chunk and multi-quantum budgets."""
    cfg, params = small_model
    arrivals = _arrivals(cfg, temperature=temperature)
    uni_eng, uni = _streams(cfg, params, arrivals, layout, chunk, True,
                            prefill_budget=budget)
    leg_eng, leg = _streams(cfg, params, arrivals, layout, chunk, False,
                            prefill_budget=budget)
    assert uni == leg and len(uni) == len(arrivals)
    assert uni_eng.unified and not leg_eng.unified
    if layout == "paged":
        assert uni_eng.blocks_in_use == 0  # every block returned at drain


def test_unified_matches_unchunked(small_model):
    """The fused path also reproduces the whole-prompt admission engine's
    streams (transitively: unified == per-chunk == unchunked)."""
    cfg, params = small_model
    arrivals = _arrivals(cfg, temperature=0.7, seed=5)
    _, base = _streams(cfg, params, arrivals, "paged", 0, True)
    _, uni = _streams(cfg, params, arrivals, "paged", 8, True)
    assert uni == base


@pytest.mark.parametrize("unified", [True, False])
def test_unified_preemption_equivalence(small_model, unified):
    """An overcommitted pool preempts and recomputes under the unified
    step exactly as under the split path: streams stay byte-identical to
    an uncontended run, and preemptions actually fire."""
    cfg, params = small_model
    rng = np.random.default_rng(1)
    prompts = [rng.integers(1, cfg.vocab_size, size=int(rng.integers(10, 25)))
               for _ in range(8)]

    def run(**kw):
        eng = ServingEngine(cfg, params, max_batch=3, max_len=64,
                            prompt_bucket=8, seed=3, cache_layout="paged",
                            prefill_chunk=4, kv_block_size=8, **kw)
        for p in prompts:
            eng.submit(p, SamplingParams(max_new_tokens=10, temperature=0.8))
        return {r.uid: list(r.output_tokens) for r in eng.run()}, eng

    base, _ = run(unified_step=False)
    got, eng = run(unified_step=unified, preemption="recompute",
                   kv_num_blocks=10)
    assert got == base
    assert eng.preemptions > 0, "pool never ran dry: test lost its teeth"


def test_dispatches_per_step_bounded(small_model):
    """A chunked unified engine (no preemption) spends at most two device
    dispatches per engine step — one fused step plus at most one batched
    admission row-reset — however many prefill cursors are in flight."""
    cfg, params = small_model
    arrivals = _arrivals(cfg, n=8, temperature=0.7, seed=9)
    for layout in ("contiguous", "paged"):
        eng, _ = _streams(cfg, params, arrivals, layout, 4, True,
                          prefill_budget=12)
        assert eng._dispatch_samples, "no steps recorded"
        assert max(eng._dispatch_samples) <= 2, (
            layout, eng._dispatch_samples)


def test_unified_budget_semantics_preserved(small_model):
    """The packed frontier replicates the legacy budget loop: per-step
    prompt progress is bounded by the budget, and a head chunk that does
    not fit stops the scan (FCFS, no work-stealing past the head)."""
    cfg, params = small_model
    eng = ServingEngine(cfg, params, max_batch=2, max_len=64,
                        prompt_bucket=8, prefill_chunk=8, prefill_budget=8)
    rng = np.random.default_rng(3)
    eng.submit(rng.integers(1, cfg.vocab_size, 24),
               SamplingParams(max_new_tokens=2))
    eng.submit(rng.integers(1, cfg.vocab_size, 24),
               SamplingParams(max_new_tokens=2))
    eng.step()  # both admitted; budget covers one 8-token chunk (head only)
    curs = [c for c in eng._cursors if c is not None]
    assert sorted(c.next for c in curs) == [0, 8]
    eng.step()
    curs = [c for c in eng._cursors if c is not None]
    assert sorted(c.next for c in curs) == [0, 16]


def test_pad_right_prefix_block_sharing(small_model):
    """Right-aligned bucketing: two prompts sharing a prefix but with
    *different-length* suffixes reuse the same cached blocks (left
    padding would shift the shared tokens onto different boundaries)."""
    cfg, params = small_model
    shared = np.arange(1, 13)  # 12 tokens = 3 full blocks of 4

    def run(pad_side):
        eng = ServingEngine(cfg, params, max_batch=2, max_len=64,
                            prompt_bucket=8, cache_layout="paged",
                            prefill_chunk=4, kv_block_size=4,
                            prefix_cache=True, pad_side=pad_side)
        eng.submit(np.concatenate([shared, [60, 61]]),
                   SamplingParams(max_new_tokens=4))
        eng.run()
        eng.submit(np.concatenate([shared, [70, 71, 72]]),
                   SamplingParams(max_new_tokens=4))
        eng.run()
        return eng.latency_summary()

    right = run("right")
    assert right["prefix_blocks_reused"] >= 2
    assert right["prefix_block_hits"] >= 2
    # same workload, left padding: the unequal suffix lengths misalign the
    # shared prefix, so no block can match
    left = run("left")
    assert left["prefix_blocks_reused"] == 0


@pytest.mark.parametrize("layout", ["contiguous", "paged"])
@pytest.mark.parametrize("chunk", [0, 8])
def test_pad_right_stream_equivalence(small_model, layout, chunk):
    """pad_side='right' engines agree between the unified and per-chunk
    paths (right padding changes RoPE positions vs 'left', so the
    invariant is unified == legacy *within* the padding mode)."""
    cfg, params = small_model
    arrivals = _arrivals(cfg, temperature=0.7, seed=11)
    _, uni = _streams(cfg, params, arrivals, layout, chunk, True,
                      pad_side="right")
    _, leg = _streams(cfg, params, arrivals, layout, chunk, False,
                      pad_side="right")
    assert uni == leg and len(uni) == len(arrivals)


def test_summary_reports_step_economics(small_model):
    """latency_summary carries the new step-economics and per-prefix
    residency keys."""
    cfg, params = small_model
    eng = ServingEngine(cfg, params, max_batch=2, max_len=64,
                        prompt_bucket=8, cache_layout="paged",
                        prefill_chunk=8, prefix_cache=True)
    rng = np.random.default_rng(0)
    eng.submit(rng.integers(1, cfg.vocab_size, 12),
               SamplingParams(max_new_tokens=4))
    eng.run()
    s = eng.latency_summary()
    assert s["steps_per_sec"] > 0
    assert s["dispatches_per_step_p95"] >= 1
    assert s["dispatches_per_step_p50"] <= s["dispatches_per_step_p95"]
    for key in ("prefix_block_hits", "prefix_block_misses",
                "prefix_block_evictions", "prefix_hashes_tracked",
                "prefix_blocks_resident"):
        assert key in s, key


# -- hypothesis: the dispatch bound holds for random workloads ----------------

try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:  # property test degrades to a skip, module still runs
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:

    _MODEL_CACHE = {}

    def _prop_model():
        if "m" not in _MODEL_CACHE:
            cfg = get_config("qwen1.5-0.5b", smoke=True)
            params, _ = model_lib.init(cfg, jax.random.PRNGKey(0))
            _MODEL_CACHE["m"] = (cfg, params)
        return _MODEL_CACHE["m"]

    @given(
        layout=st.sampled_from(["contiguous", "paged"]),
        chunk=st.sampled_from([2, 4, 8]),
        budget_mult=st.integers(1, 3),
        n=st.integers(2, 6),
        seed=st.integers(0, 2**16),
    )
    @settings(max_examples=6, deadline=None)
    def test_dispatch_bound_invariant(layout, chunk, budget_mult, n, seed):
        """Hypothesis: any chunked non-preemptive unified engine serves
        any Poisson workload at <= 2 device dispatches per engine step."""
        cfg, params = _prop_model()
        spec = WorkloadSpec(
            arrival_rate=0.0, num_requests=n,
            prompt_len=LengthDist(kind="lognormal", mean=14.0, low=2,
                                  high=40),
            output_len=LengthDist(kind="uniform", low=1, high=7),
            temperature=0.7, top_k=8, seed=seed,
        )
        eng = ServingEngine(cfg, params, max_batch=3, max_len=64,
                            prompt_bucket=8, cache_layout=layout,
                            prefill_chunk=chunk,
                            prefill_budget=chunk * budget_mult)
        for a in poisson_trace(spec, cfg.vocab_size):
            eng.submit(a.prompt, a.params)
        eng.run()
        assert eng._dispatch_samples and max(eng._dispatch_samples) <= 2

else:

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_dispatch_bound_invariant():
        pass
