"""Speculative decoding suite.

The contract under test: prompt-lookup drafting with single-dispatch
batched verification changes ONLY the step economics (tokens emitted per
device dispatch), never the tokens.  Across {contiguous, paged} x
{greedy, sampled} x {chunked, unchunked} x preemption, the speculative
engine must emit token streams byte-identical to the non-speculative one
for the same seed — with accepts AND rejections both proven to fire.
The adversarial-drafter test is the strongest form of the invariant:
even a drafter that always proposes garbage cannot change the stream,
because the emitted token is always the target model's own sample and a
rejected suffix's cache entries are overwritten before they are read.
"""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as model_lib
from repro.serving import engine as engine_mod
from repro.serving.engine import ServingEngine, prompt_lookup_draft
from repro.serving.sampling import SamplingParams
from repro.serving.workload import (LengthDist, WorkloadSpec,
                                    lookup_friendly_trace, poisson_trace)

pytestmark = pytest.mark.speculative


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("qwen1.5-0.5b", smoke=True)
    params, _ = model_lib.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _arrivals(cfg, n=5, temperature=0.0, seed=3, out_hi=24):
    spec = WorkloadSpec(
        arrival_rate=0.0, num_requests=n,
        prompt_len=LengthDist(kind="lognormal", mean=16.0, low=2, high=40),
        output_len=LengthDist(kind="uniform", low=4, high=out_hi),
        temperature=temperature, top_k=8, seed=seed,
    )
    return poisson_trace(spec, cfg.vocab_size)


def _streams(cfg, params, arrivals, *, speculative="off", spec_tokens=4,
             max_batch=2, **kw):
    eng = ServingEngine(cfg, params, max_batch=max_batch, max_len=64,
                        prompt_bucket=8, speculative=speculative,
                        spec_tokens=spec_tokens, **kw)
    for a in arrivals:
        eng.submit(a.prompt, a.params)
    finished = eng.run()
    return eng, {r.uid: list(r.output_tokens) for r in finished}


# -- the drafter --------------------------------------------------------------

def test_prompt_lookup_draft():
    """Longest trailing n-gram wins; most recent full-k continuation
    preferred; no match -> empty draft."""
    # trailing [1, 2] matches at index 0; continuation is [3, 1, 2]
    assert prompt_lookup_draft([1, 2, 3, 1, 2], 3) == [3, 1, 2]
    # the n=3 trailing gram [5,5,5] matches at 0 with just 1 token after it
    assert prompt_lookup_draft([5, 5, 5, 5], 2) == [5]
    # two occurrences of [1,2]: the recent one (index 3) has the full-k
    # continuation and wins over the older one
    assert prompt_lookup_draft([1, 2, 9, 1, 2, 7, 1, 2], 1,
                               ngram_max=2) == [7]
    assert prompt_lookup_draft([1, 2, 3], 2) == []
    assert prompt_lookup_draft([], 4) == []
    assert prompt_lookup_draft([1, 2, 3, 1, 2], 0) == []


# -- stream equivalence matrix ------------------------------------------------

@pytest.mark.parametrize("temperature", [0.0, 0.7])
@pytest.mark.parametrize("layout", ["contiguous", "paged"])
@pytest.mark.parametrize("chunk", [0, 8])
def test_speculative_matches_plain(small_model, layout, temperature, chunk):
    """Speculative streams == non-speculative streams, every layout,
    greedy and sampled, chunked and unchunked — and drafts actually
    verify (the equivalence would be vacuous if nothing were accepted)."""
    cfg, params = small_model
    arrivals = _arrivals(cfg, temperature=temperature)
    _, base = _streams(cfg, params, arrivals, cache_layout=layout,
                       prefill_chunk=chunk)
    eng, spec = _streams(cfg, params, arrivals, cache_layout=layout,
                         prefill_chunk=chunk, speculative="lookup")
    assert spec == base and len(spec) == len(arrivals)
    s = eng.latency_summary()
    assert s["drafted_tokens"] > 0
    assert s["accepted_tokens"] > 0          # accepts fired
    assert 0.0 <= s["spec_accept_rate"] <= 1.0
    assert s["tokens_per_dispatch"] > 1.0    # verifies emitted multi-token
    if layout == "paged":
        assert eng.blocks_in_use == 0        # every block returned at drain


@pytest.mark.parametrize("layout", ["contiguous", "paged"])
def test_rejections_fire_and_do_not_corrupt(small_model, layout,
                                            monkeypatch):
    """An adversarial drafter that always proposes garbage: every draft
    token is rejected, yet the stream stays byte-identical — the emitted
    token is always the target sample, and rejected suffixes' cache
    writes are overwritten/masked before any later read."""
    cfg, params = small_model
    arrivals = _arrivals(cfg, temperature=0.7)
    _, base = _streams(cfg, params, arrivals, cache_layout=layout,
                       prefill_chunk=8)
    # tokens the tiny smoke model all but never emits in sequence
    monkeypatch.setattr(engine_mod, "prompt_lookup_draft",
                        lambda hist, k, ngram_max=3: [3, 1, 4, 1][:k])
    eng, spec = _streams(cfg, params, arrivals, cache_layout=layout,
                         prefill_chunk=8, speculative="lookup")
    assert spec == base
    s = eng.latency_summary()
    assert s["drafted_tokens"] > 0
    assert s["accepted_tokens"] < s["drafted_tokens"]  # rejections fired
    assert s["spec_accept_rate"] < 1.0


def test_speculative_under_preemption(small_model):
    """Pool overcommit with lazy growth: the verify window's extra blocks
    are grown before the dispatch, rejected-suffix blocks are rolled
    back, preempted requests recompute and resume mid-stream — and the
    streams still match the non-speculative preempting engine."""
    cfg, params = small_model
    arrivals = _arrivals(cfg, temperature=0.7, n=6, seed=11, out_hi=30)
    kw = dict(cache_layout="paged", prefill_chunk=8,
              preemption="recompute", kv_num_blocks=10, kv_block_size=8,
              max_batch=3)
    _, base = _streams(cfg, params, arrivals, **kw)
    eng, spec = _streams(cfg, params, arrivals, speculative="lookup", **kw)
    assert spec == base
    assert eng.preemptions > 0               # overcommit actually bit
    assert eng.blocks_in_use == 0
    assert len(eng._pool.free_stack) == eng.num_blocks - 1


def test_speculative_dispatch_bound(small_model):
    """Speculation preserves the unified step's <= 2 dispatches per engine
    step (the fused verify replaces the fused decode, 1:1), and the
    emission accounting balances: every verify emits its accepted tokens
    plus one bonus sample."""
    cfg, params = small_model
    arrivals = _arrivals(cfg, temperature=0.0)
    eng, _ = _streams(cfg, params, arrivals, cache_layout="paged",
                      prefill_chunk=8, speculative="lookup")
    assert max(eng._dispatch_samples) <= 2
    assert eng._decode_tokens == eng._spec_verifies + eng._accepted_tokens


# -- construction-time gating -------------------------------------------------

def test_speculative_validation(small_model):
    cfg, params = small_model
    with pytest.raises(ValueError, match="speculative"):
        ServingEngine(cfg, params, speculative="banana")
    with pytest.raises(ValueError, match="spec-tokens"):
        ServingEngine(cfg, params, speculative="lookup", spec_tokens=0)
    hybrid = get_config("recurrentgemma-2b", smoke=True)
    hparams, _ = model_lib.init(hybrid, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="rewind"):
        ServingEngine(hybrid, hparams, speculative="lookup")
    # speculative='off' ignores spec_tokens and runs the plain step
    eng = ServingEngine(cfg, params, speculative="off", spec_tokens=0)
    assert eng.spec_k == 0


# -- the showcase workload ----------------------------------------------------

def test_lookup_friendly_trace_accepts(small_model):
    """The tiled-motif trace is what the drafter thrives on: greedy decode
    cycles the motif, so accept rates are near-total and one dispatch
    emits multi-token stretches."""
    cfg, params = small_model
    arrivals = lookup_friendly_trace(cfg.vocab_size, num_requests=4,
                                     motif_len=8, repeats=3, max_new=24)
    assert all(len(a.prompt) == 24 for a in arrivals)
    _, base = _streams(cfg, params, arrivals, prefill_chunk=8)
    eng, spec = _streams(cfg, params, arrivals, prefill_chunk=8,
                         speculative="lookup", spec_tokens=6)
    assert spec == base
    s = eng.latency_summary()
    assert s["spec_accept_rate"] > 0.5
    assert s["tokens_per_dispatch"] > 2.0


# -- metrics guards (regression) ----------------------------------------------

def test_single_token_request_metrics(small_model):
    """max_new_tokens=1 used to divide by zero in tpot_s; a finished run
    with such requests must report tpot 0.0 and finite summary values."""
    cfg, params = small_model
    eng = ServingEngine(cfg, params, max_batch=2, max_len=64,
                        prompt_bucket=8)
    eng.submit(np.arange(1, 9, dtype=np.int32),
               SamplingParams(max_new_tokens=1))
    finished = eng.run()
    assert len(finished) == 1
    assert finished[0].tpot_s == 0.0
    s = eng.latency_summary()
    assert np.isfinite(s["tpot_ms"])
    assert s["output_tokens"] == 1


def test_unfinished_request_tpot_is_zero():
    """A request that never started (or never finished) has meaningless
    timestamps; tpot_s must not divide them into garbage."""
    from repro.serving.engine import Request
    r = Request(uid=0, prompt=np.arange(4, dtype=np.int32))
    assert r.tpot_s == 0.0
    r.output_tokens = [1, 2, 3]
    r.first_token_time = 10.0
    r.finish_time = 5.0   # corrupt ordering: still no garbage division
    assert r.tpot_s == 0.0
