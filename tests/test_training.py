"""Training substrate: optimizer, grad accumulation, checkpoint/resume,
fault tolerance, data determinism."""

import os
import signal
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import Prefetcher
from repro.data.synthetic import SyntheticConfig, SyntheticDataset
from repro.data.tokenbin import TokenBinDataset, write_tokenbin
from repro.training import checkpoint as ckpt_lib
from repro.training import step as step_lib
from repro.training.fault import (PreemptionHandler, RunPosition,
                                  StragglerWatchdog)
from repro.training.optimizer import (AdamW, constant_schedule,
                                      warmup_cosine_schedule)


def test_adamw_minimizes_quadratic():
    opt = AdamW(schedule=constant_schedule(0.1), weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state = opt.update(grads, state, params)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_warmup_cosine_shape():
    sched = warmup_cosine_schedule(1.0, 10, 100, min_ratio=0.1)
    assert float(sched(jnp.asarray(0))) == 0.0
    assert float(sched(jnp.asarray(10))) == pytest.approx(1.0)
    assert float(sched(jnp.asarray(100))) == pytest.approx(0.1, rel=1e-3)
    # monotone decay after warmup
    vals = [float(sched(jnp.asarray(s))) for s in range(10, 101, 10)]
    assert all(a >= b for a, b in zip(vals, vals[1:]))


def test_grad_accumulation_equals_full_batch():
    cfg = get_config("qwen1.5-0.5b", smoke=True)
    opt = AdamW(schedule=constant_schedule(1e-3))
    state, _ = step_lib.init_state(cfg, opt, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 16)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 16)), jnp.int32),
    }
    s1 = jax.jit(step_lib.make_train_step(cfg, opt, remat=False, microbatches=1))
    s4 = jax.jit(step_lib.make_train_step(cfg, opt, remat=False, microbatches=4))
    st1, m1 = s1(state, batch)
    st4, m4 = s4(state, batch)
    # loss means agree; updated params agree to fp tolerance
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=1e-4)
    for a, b in zip(jax.tree.leaves(st1.params), jax.tree.leaves(st4.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-3, atol=5e-5)


def test_checkpoint_resume_and_gc(tmp_path):
    d = str(tmp_path)
    tree = {"w": np.arange(10, dtype=np.float32)}
    for step in (10, 20, 30, 40):
        ckpt_lib.save(d, step, {"w": tree["w"] * step}, keep=2,
                      metadata=RunPosition(step, 0, step, 0).to_metadata())
    assert ckpt_lib.latest_step(d) == 40
    dirs = [x for x in os.listdir(d) if x.startswith("step_")]
    assert len(dirs) == 2  # GC keeps the last 2
    restored, manifest = ckpt_lib.restore(d, tree)
    np.testing.assert_array_equal(restored["w"], tree["w"] * 40)
    assert RunPosition.from_metadata(manifest).data_offset == 40


def test_checkpoint_atomicity(tmp_path):
    """A leftover .tmp dir never shadows a durable checkpoint."""
    d = str(tmp_path)
    ckpt_lib.save(d, 1, {"w": np.ones(3, np.float32)})
    os.makedirs(os.path.join(d, "step_00000002.tmp"))  # simulated crash
    assert ckpt_lib.latest_step(d) == 1
    restored, _ = ckpt_lib.restore(d, {"w": np.zeros(3, np.float32)})
    np.testing.assert_array_equal(restored["w"], np.ones(3))


def test_preemption_handler_cooperative():
    h = PreemptionHandler().install()
    assert not h.preemption_requested
    os.kill(os.getpid(), signal.SIGTERM)
    time.sleep(0.05)
    assert h.preemption_requested
    h.uninstall()


def test_straggler_watchdog_flags_outliers():
    wd = StragglerWatchdog(alpha=0.5, threshold=2.0)
    flagged = []
    wd.on_straggler = lambda t: flagged.append(t.step)
    for i in range(5):
        wd.start_step()
        time.sleep(0.01)
        wd.end_step(i)
    wd.start_step()
    time.sleep(0.08)  # 8x normal
    wd.end_step(5)
    assert wd.straggler_count == 1 and flagged == [5]
    # EWMA not poisoned: next normal step is not flagged
    wd.start_step(); time.sleep(0.01); t = wd.end_step(6)
    assert not t.is_straggler


def test_synthetic_data_deterministic_and_rank_disjoint():
    ds = SyntheticDataset(SyntheticConfig(vocab_size=64, seq_len=8,
                                          batch_size=4, seed=1))
    a = ds.batch_at(3, rank=0)
    b = ds.batch_at(3, rank=0)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = ds.batch_at(3, rank=1)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # next-token supervision
    full_a = np.concatenate([a["tokens"], a["labels"][:, -1:]], axis=1)
    np.testing.assert_array_equal(full_a[:, 1:], a["labels"])


def test_tokenbin_roundtrip_and_sharding(tmp_path):
    path = str(tmp_path / "data.tokbin")
    tokens = np.arange(1000) % 97
    write_tokenbin(path, tokens, vocab_size=97)
    ds0 = TokenBinDataset(path, seq_len=16, batch_size=2, rank=0, world=2)
    ds1 = TokenBinDataset(path, seq_len=16, batch_size=2, rank=1, world=2)
    b0 = ds0.batch_at(0, 0)
    b1 = ds1.batch_at(0, 0)
    assert b0["tokens"].shape == (2, 16)
    assert not np.array_equal(b0["tokens"], b1["tokens"])  # disjoint shards
    # determinism + resumability: same (epoch, offset) -> same batch
    np.testing.assert_array_equal(ds0.batch_at(1, 3)["tokens"],
                                  ds0.batch_at(1, 3)["tokens"])
    np.testing.assert_array_equal(b0["labels"][:, :-1], b0["tokens"][:, 1:])


def test_prefetcher_orders_and_propagates_errors():
    it = Prefetcher(iter(range(10)), depth=3)
    assert list(it) == list(range(10))

    def boom():
        yield 1
        raise RuntimeError("boom")

    it = Prefetcher(boom(), depth=2)
    assert next(it) == 1
    with pytest.raises(RuntimeError):
        next(it)
        next(it)


def test_train_driver_end_to_end(tmp_path):
    """launch.train: loss decreases, checkpoint resume continues the run."""
    from repro.launch.train import build_argparser, train

    ck = str(tmp_path / "ck")
    args = build_argparser().parse_args([
        "--arch", "qwen1.5-0.5b", "--smoke", "--steps", "12", "--batch", "4",
        "--seq-len", "32", "--ckpt-dir", ck, "--ckpt-every", "6",
        "--lr", "3e-3", "--warmup", "2",
    ])
    out = train(args)
    assert out["steps"] == 12
    assert out["loss_last"] < out["loss_first"]
    assert ckpt_lib.latest_step(ck) == 12
    # resume: runs the remaining steps only
    args2 = build_argparser().parse_args([
        "--arch", "qwen1.5-0.5b", "--smoke", "--steps", "16", "--batch", "4",
        "--seq-len", "32", "--ckpt-dir", ck, "--lr", "3e-3", "--warmup", "2",
    ])
    out2 = train(args2)
    assert out2["final_step"] == 16
    assert out2["steps"] == 4  # only 12 -> 16
