"""Property-based tests (hypothesis) on system invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import units
from repro.core import cache as cache_prof
from repro.models.config import ModelConfig
from repro.serving.sampling import SamplingParams, sample
from repro.training.optimizer import clip_by_global_norm
from repro.training.step import cross_entropy

SETTINGS = dict(max_examples=25, deadline=None)


# -- units: conversions are exact ratios --------------------------------------

@given(n=st.integers(min_value=0, max_value=10**15))
@settings(**SETTINGS)
def test_units_ratio(n):
    assert units.convert(n, "GB") * 1000**3 == pytest.approx(n, rel=1e-12)
    assert units.convert(n, "GiB") * 1024**3 == pytest.approx(n, rel=1e-12)
    # GiB value never exceeds GB value
    assert units.convert(n, "GiB") <= units.convert(n, "GB")


# -- cache: eval-shape profiler == closed-form, for random dense configs ------

@given(
    layers=st.integers(1, 6),
    kv=st.sampled_from([1, 2, 4]),
    q_mult=st.sampled_from([1, 2, 4]),
    hd=st.sampled_from([8, 16, 32]),
    batch=st.integers(1, 8),
    seq=st.sampled_from([16, 64, 256]),
)
@settings(**SETTINGS)
def test_cache_formula_invariant(layers, kv, q_mult, hd, batch, seq):
    cfg = ModelConfig(
        name="prop", num_layers=layers, d_model=64, num_heads=kv * q_mult,
        num_kv_heads=kv, head_dim=hd, d_ff=128, vocab_size=64,
        dtype="bfloat16",
    ).validate()
    rep = cache_prof.profile_cache(cfg, batch, seq)
    assert rep.kv_bytes == 2 * layers * batch * seq * kv * hd * 2
    assert rep.kv_bytes == cache_prof.analytic_kv_bytes(cfg, batch, seq)
    # cache scales exactly linearly in batch
    rep2 = cache_prof.profile_cache(cfg, batch * 2, seq)
    assert rep2.kv_bytes == 2 * rep.kv_bytes


# -- cross entropy: bounds and exactness ---------------------------------------

@given(
    b=st.integers(1, 4), s=st.integers(1, 8), v=st.sampled_from([7, 32]),
    seed=st.integers(0, 2**16),
)
@settings(**SETTINGS)
def test_cross_entropy_bounds(b, s, v, seed):
    key = jax.random.PRNGKey(seed)
    logits = jax.random.normal(key, (b, s, v)) * 3
    labels = jax.random.randint(jax.random.fold_in(key, 1), (b, s), 0, v)
    loss, aux = cross_entropy(logits, labels, z_loss=0.0)
    # NLL of a v-way distribution is non-negative; uniform gives log(v)
    assert float(loss) >= -1e-5
    uniform_loss, _ = cross_entropy(jnp.zeros((b, s, v)), labels, z_loss=0.0)
    assert float(uniform_loss) == pytest.approx(np.log(v), rel=1e-5)
    assert 0.0 <= float(aux["accuracy"]) <= 1.0


# -- clipping: result norm never exceeds the bound ------------------------------

@given(scale=st.floats(0.01, 100.0), seed=st.integers(0, 2**16))
@settings(**SETTINGS)
def test_clip_global_norm(scale, seed):
    key = jax.random.PRNGKey(seed)
    tree = {"a": jax.random.normal(key, (17,)) * scale,
            "b": jax.random.normal(jax.random.fold_in(key, 1), (3, 5)) * scale}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    out_norm = float(jnp.sqrt(sum(jnp.sum(jnp.square(x))
                                  for x in jax.tree.leaves(clipped))))
    assert out_norm <= 1.0 + 1e-4
    if float(norm) <= 1.0:  # no-op when already within bound
        for a, b in zip(jax.tree.leaves(clipped), jax.tree.leaves(tree)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


# -- sampling: greedy == argmax; top-k never escapes the top-k set --------------

@given(b=st.integers(1, 4), v=st.integers(4, 64), k=st.integers(1, 4),
       seed=st.integers(0, 2**16))
@settings(**SETTINGS)
def test_sampling_invariants(b, v, k, seed):
    key = jax.random.PRNGKey(seed)
    logits = jax.random.normal(key, (b, v)) * 2
    greedy = sample(logits, SamplingParams(temperature=0.0), key)
    np.testing.assert_array_equal(np.asarray(greedy),
                                  np.asarray(jnp.argmax(logits, -1)))
    k = min(k, v)
    tok = sample(logits, SamplingParams(temperature=1.0, top_k=k),
                 jax.random.fold_in(key, 7))
    topk = jax.lax.top_k(logits, k)[1]
    for i in range(b):
        assert int(tok[i]) in np.asarray(topk[i])


# -- linear recurrence: kernel == sequential loop, random decays ----------------

@given(s=st.integers(1, 33), w=st.sampled_from([4, 8]),
       seed=st.integers(0, 2**16))
@settings(**SETTINGS)
def test_linear_recurrence_property(s, w, seed):
    from repro.kernels.linear_recurrence import ref

    rng = np.random.default_rng(seed)
    a = rng.uniform(0.5, 1.0, (1, s, w)).astype(np.float32)
    b = rng.standard_normal((1, s, w)).astype(np.float32)
    h0 = rng.standard_normal((1, w)).astype(np.float32)
    got = np.asarray(ref.linear_recurrence(
        jnp.asarray(a), jnp.asarray(b), jnp.asarray(h0)))
    h = h0[0].copy()
    for t in range(s):
        h = a[0, t] * h + b[0, t]
        np.testing.assert_allclose(got[0, t], h, rtol=2e-4, atol=1e-5)


# -- MoE: with no capacity pressure, outputs = weighted expert mixture ----------

@given(seed=st.integers(0, 2**16))
@settings(max_examples=10, deadline=None)
def test_moe_combine_is_convex_mixture(seed):
    from repro.models import moe as moe_lib

    cfg = ModelConfig(
        name="prop-moe", num_layers=1, d_model=16, num_heads=2,
        num_kv_heads=2, d_ff=32, vocab_size=32, num_experts=4,
        num_experts_per_tok=2, moe_capacity_factor=16.0,
        dtype="float32", param_dtype="float32",
    ).validate()
    from repro.models.layers import Maker, split_params

    key = jax.random.PRNGKey(seed)
    params, _ = split_params(moe_lib.make_moe(Maker(key, jnp.float32), cfg))
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 3, 16))
    out = moe_lib.apply_moe(params, x, cfg)
    # manual: route, run every expert densely, combine
    T = 6
    xf = x.reshape(T, 16)
    logits = xf @ params["router"]
    w, idx = moe_lib.route(logits, 2)
    dense = []
    for e in range(4):
        g = xf @ params["wg"][e]
        u = xf @ params["wu"][e]
        dense.append((jax.nn.silu(g) * u) @ params["wd"][e])
    dense = jnp.stack(dense, 1)  # (T, E, d)
    expected = jnp.einsum("tk,tkd->td", w,
                          jnp.take_along_axis(dense, idx[..., None], axis=1))
    np.testing.assert_allclose(np.asarray(out.reshape(T, 16)),
                               np.asarray(expected), rtol=2e-3, atol=2e-4)


# -- chunked-prefill scheduler invariants under random workloads ----------------

_SERVE_MODEL = {}


def _serve_model():
    """Tiny serving model, built once across hypothesis examples."""
    if not _SERVE_MODEL:
        from repro.models import model as model_lib

        cfg = ModelConfig(
            name="prop-serve", num_layers=2, d_model=32, num_heads=2,
            num_kv_heads=2, d_ff=64, vocab_size=128,
            dtype="float32", param_dtype="float32",
        ).validate()
        params, _ = model_lib.init(cfg, jax.random.PRNGKey(0))
        _SERVE_MODEL["cfg"], _SERVE_MODEL["params"] = cfg, params
    return _SERVE_MODEL["cfg"], _SERVE_MODEL["params"]


@given(
    seed=st.integers(0, 2**16),
    chunk=st.integers(1, 20),
    n=st.integers(2, 4),
)
@settings(max_examples=6, deadline=None)
def test_chunked_scheduler_invariants(seed, chunk, n):
    """Random Poisson workloads x random chunk sizes: no slot decodes
    before its final chunk lands, paged block accounting balances to zero
    after the drain, and blocks-in-use never exceeds what admission
    reserved (so the fused step's append can never allocate)."""
    from repro.serving.engine import ServingEngine
    from repro.serving.workload import LengthDist, WorkloadSpec, poisson_trace

    cfg, params = _serve_model()
    spec = WorkloadSpec(
        arrival_rate=0.0, num_requests=n,
        prompt_len=LengthDist(kind="uniform", low=2, high=40),
        output_len=LengthDist(kind="uniform", low=1, high=6),
        temperature=0.7, top_k=8, seed=seed,
    )
    eng = ServingEngine(cfg, params, max_batch=2, max_len=64,
                        prompt_bucket=8, cache_layout="paged",
                        kv_block_size=16, prefill_chunk=chunk, seed=seed)
    total_free = len(eng._free_blocks)
    for a in poisson_trace(spec, cfg.vocab_size):
        eng.submit(a.prompt, a.params)

    for _ in range(500):
        if not eng.busy:
            break
        eng.step()
        reserved = 0
        for slot in range(eng.max_batch):
            req, cur = eng.slots[slot], eng._cursors[slot]
            if cur is not None:
                # prefilling: not decode-eligible, emits nothing
                assert cur.req is req
                assert req.output_tokens == [] and req.first_token_time == 0.0
                assert not bool(eng._state["active"][slot])
                assert 0 <= cur.next < cur.plen  # open cursors retire at plen
            if req is not None:
                nb = eng._blocks_for(
                    eng._bucketed(min(len(req.prompt), eng.max_len - 1)),
                    req.params.max_new_tokens)
                assert len(eng._slot_blocks[slot]) <= nb
                reserved += len(eng._slot_blocks[slot])
            else:
                assert not eng._slot_blocks[slot]
        # in-use == sum of live reservations; usage never exceeds them
        assert eng.blocks_in_use == reserved
        assert eng.kv_bytes_in_use() <= (
            eng._n_attn_layers * reserved * eng.block_size * eng._kv_tok_bytes)
    assert not eng.busy, "workload failed to drain"
    eng.flush()
    # block accounting balances to zero after the drain + flush
    assert eng.blocks_in_use == 0
    assert len(eng._free_blocks) == total_free
    assert all(not b for b in eng._slot_blocks)
    assert len(eng.finished) == n


# -- prefix-cache refcount/eviction invariants under random workloads -----------

@given(
    seed=st.integers(0, 2**16),
    chunk=st.integers(0, 12),
    n=st.integers(3, 6),
    pool_extra=st.integers(0, 8),
)
@settings(max_examples=6, deadline=None)
def test_prefix_cache_invariants(seed, chunk, n, pool_extra):
    """Random shared-prefix Poisson workloads x {chunked, unchunked} x pool
    sizes: at every step the free stack, the evictable LRU, and the live
    slot tables partition the pool (so an evicted block can never have a
    live reader), every registered block's refcount equals its number of
    live owners, and after the drain all refcounts balance to zero with
    every block either free or cached-evictable."""
    from collections import Counter

    from repro.serving.engine import ServingEngine
    from repro.serving.workload import shared_prefix_trace

    cfg, params = _serve_model()
    eng = ServingEngine(cfg, params, max_batch=2, max_len=64,
                        prompt_bucket=8, cache_layout="paged",
                        kv_block_size=8, kv_num_blocks=9 + pool_extra,
                        prefill_chunk=chunk, prefix_cache=True, seed=seed)
    rng = np.random.default_rng(seed)
    arrivals = shared_prefix_trace(
        cfg.vocab_size, num_requests=n,
        shared_prefix_len=int(rng.integers(8, 28)), num_prefixes=2,
        suffix_len=int(rng.integers(1, 9)),
        max_new=int(rng.integers(1, 5)), arrival_rate=0.0, seed=seed,
        temperature=0.7, top_k=8)
    for a in arrivals:
        eng.submit(a.prompt, a.params)

    pool, all_blocks = eng._pool, set(range(1, eng.num_blocks))
    for _ in range(500):
        if not eng.busy:
            break
        eng.step()
        free, evict = set(pool.free_stack), set(pool.evictable)
        owners = Counter(b for blocks in eng._slot_blocks for b in blocks)
        live = set(owners)
        # free / evictable / live partition the pool: evicted-or-idle
        # blocks never have live readers, nothing is lost or double-held
        assert len(free) == len(pool.free_stack)  # no duplicates
        assert not (free & evict) and not (free & live) and not (evict & live)
        assert free | evict | live == all_blocks
        # refcount == number of live owners for every registered block;
        # unregistered blocks are private (exactly one owner)
        for blk, r in pool.refs.items():
            assert r == owners.get(blk, 0)
        for blk, c in owners.items():
            if blk not in pool.refs:
                assert c == 1
        # only registered blocks can be published as ready
        assert pool.ready <= set(pool.hash_of)
        assert eng.blocks_in_use == len(live)
    assert not eng.busy, "workload failed to drain"
    eng.flush()
    assert len(eng.finished) == n
    # refcounts balance to zero; every block is free or cached-evictable
    assert eng.blocks_in_use == 0
    assert all(r == 0 for r in pool.refs.values())
    assert len(pool.free_stack) + len(pool.evictable) == eng.num_blocks - 1
    assert all(not b for b in eng._slot_blocks)


# -- speculative decoding: economics invariants --------------------------------

@pytest.mark.speculative
@given(
    seed=st.integers(0, 2**16),
    k=st.integers(1, 6),
    n=st.integers(2, 4),
    spec_on=st.booleans(),
)
@settings(max_examples=6, deadline=None)
def test_speculative_invariants(seed, k, n, spec_on):
    """Random Poisson workloads x random draft depths x spec on/off: the
    accept rate stays in [0, 1], every verify dispatch emits exactly its
    accepted draft tokens plus one bonus sample (emitted == accepted +
    verifies), tokens/dispatch is >= 1, and the unified chunked engine
    holds the <= 2 dispatches/step bound with speculation on or off."""
    from repro.serving.engine import ServingEngine
    from repro.serving.workload import LengthDist, WorkloadSpec, poisson_trace

    cfg, params = _serve_model()
    spec = WorkloadSpec(
        arrival_rate=0.0, num_requests=n,
        prompt_len=LengthDist(kind="uniform", low=2, high=40),
        output_len=LengthDist(kind="uniform", low=1, high=12),
        temperature=0.7, top_k=8, seed=seed,
    )
    eng = ServingEngine(cfg, params, max_batch=2, max_len=64,
                        prompt_bucket=8, cache_layout="paged",
                        kv_block_size=8, prefill_chunk=8, seed=seed,
                        speculative="lookup" if spec_on else "off",
                        spec_tokens=k)
    for a in poisson_trace(spec, cfg.vocab_size):
        eng.submit(a.prompt, a.params)
    eng.run()
    assert len(eng.finished) == n
    assert max(eng._dispatch_samples) <= 2
    assert eng._decode_tokens >= eng._decode_dispatches
    s = eng.latency_summary()
    assert s["tokens_per_dispatch"] >= 1.0
    if spec_on:
        assert 0.0 <= s["spec_accept_rate"] <= 1.0
        assert s["accepted_tokens"] <= s["drafted_tokens"]
        assert eng._decode_tokens == (eng._spec_verifies
                                      + eng._accepted_tokens)
    else:
        assert "spec_accept_rate" not in s
        assert eng._drafted_tokens == 0
    assert eng.blocks_in_use == 0


# -- energy: the step-function integral is additive over tiled windows ----------

def _sample_train(rng, n):
    """Jittered sample cadence with 1-2 devices, like a real flaky sampler."""
    ts = np.cumsum(rng.uniform(1e-4, 0.3, n))
    return [(float(t),
             [float(w) for w in rng.uniform(0.0, 120.0, rng.integers(1, 3))])
            for t in ts]


@given(n=st.integers(1, 30), cuts=st.integers(1, 8),
       seed=st.integers(0, 2**16))
@settings(**SETTINGS)
def test_energy_tiling_conserves(n, cuts, seed):
    """For arbitrary jittered sample trains and arbitrary window cuts,
    tiling [t0, t1] with sub-windows reproduces integrate_joules(t0, t1)
    — the invariant per-request energy attribution stands on."""
    from repro.core.energy import integrate_joules

    rng = np.random.default_rng(seed)
    samples = _sample_train(rng, n)
    span = samples[-1][0]
    # windows deliberately overhang the sample train on both sides
    t0 = float(rng.uniform(-0.5, span))
    t1 = t0 + float(rng.uniform(1e-6, span - t0 + 0.5))
    edges = [t0] + sorted(float(e) for e in rng.uniform(t0, t1, cuts)) + [t1]
    total = integrate_joules(samples, t0, t1)
    tiled = sum(integrate_joules(samples, a, b)
                for a, b in zip(edges, edges[1:]))
    assert tiled == pytest.approx(total, rel=1e-9, abs=1e-12)


@given(n=st.integers(1, 30), seed=st.integers(0, 2**16))
@settings(**SETTINGS)
def test_energy_result_shares_ledger_with_joules_between(n, seed):
    """result().joules and joules_between(*window) are the same integral
    — run-level and per-request accounting can never drift apart."""
    from repro.core.energy import PowerMonitor, SyntheticReader

    rng = np.random.default_rng(seed)
    mon = PowerMonitor(SyntheticReader(lambda t: 0.0))
    mon._samples = _sample_train(rng, n)
    span = mon._samples[-1][0]
    mon._t0 = float(rng.uniform(-0.5, span))
    mon._t1 = mon._t0 + float(rng.uniform(1e-6, span - mon._t0 + 0.5))
    res = mon.result()
    assert res.joules == mon.joules_between(mon._t0, mon._t1)
    assert res.avg_watts * res.duration_s == pytest.approx(
        res.joules, rel=1e-9, abs=1e-12)


# -- sharded serving: per-device pool + energy ledgers --------------------------

@pytest.mark.sharded
@given(
    seed=st.integers(0, 2**16),
    ndev=st.sampled_from([1, 2, 4]),
    chunk=st.sampled_from([0, 4, 8]),
    n=st.integers(2, 5),
)
@settings(max_examples=6, deadline=None)
def test_sharded_pool_accounting_partitions(seed, ndev, chunk, n):
    """Random Poisson workloads: per-device block accounting mirrors the
    global pool on every shard — free + in_use + evictable tiles the
    allocatable blocks, and every device reports the identical partition
    (the pool shards KV features, never blocks, so a block live on one
    device is live on all: no cross-device aliasing).  Host bookkeeping
    only — needs no multi-device host."""
    from repro.serving.engine import ServingEngine
    from repro.serving.workload import LengthDist, WorkloadSpec, poisson_trace

    cfg, params = _serve_model()
    spec = WorkloadSpec(
        arrival_rate=0.0, num_requests=n,
        prompt_len=LengthDist(kind="uniform", low=2, high=40),
        output_len=LengthDist(kind="uniform", low=1, high=10),
        temperature=0.7, top_k=8, seed=seed,
    )
    eng = ServingEngine(cfg, params, max_batch=2, max_len=64,
                        prompt_bucket=8, cache_layout="paged",
                        kv_block_size=8, prefill_chunk=chunk, seed=seed)
    for a in poisson_trace(spec, cfg.vocab_size):
        eng.submit(a.prompt, a.params)
    pool = eng._pool
    while eng.busy:
        eng.step()
        views = pool.shard_accounting(ndev)
        assert len(views) == ndev
        assert len({(v["free"], v["in_use"], v["evictable"])
                    for v in views}) == 1
        for v in views:
            assert v["free"] == len(pool.free_stack)
            assert v["evictable"] == len(pool.evictable)
            assert v["in_use"] == pool.in_use
            assert (v["free"] + v["in_use"] + v["evictable"]
                    == v["allocatable"] == max(pool.num_blocks - 1, 0))
    eng.flush()
    # drained: every shard's pool is all free/evictable again
    for v in pool.shard_accounting(ndev):
        assert v["in_use"] == 0


@pytest.mark.sharded
@given(ndev=st.integers(1, 4), n=st.integers(1, 30), cuts=st.integers(1, 8),
       seed=st.integers(0, 2**16))
@settings(**SETTINGS)
def test_device_group_energy_tilings_sum_to_aggregate(ndev, n, cuts, seed):
    """For arbitrary jittered per-device sample trains and arbitrary
    request-window cuts: per-device totals sum exactly to the aggregate
    ``result().joules``, and tiling the run window — aggregate or per
    device — reproduces the same ledger."""
    from repro.core.energy import DeviceMonitorGroup, SyntheticReader

    rng = np.random.default_rng(seed)
    group = DeviceMonitorGroup(
        [SyntheticReader(lambda t: 0.0) for _ in range(ndev)])
    for m in group.monitors:
        m._samples = _sample_train(rng, n)
    span = max(m._samples[-1][0] for m in group.monitors)
    group._t0 = float(rng.uniform(-0.5, span))
    group._t1 = group._t0 + float(rng.uniform(1e-6, span - group._t0 + 0.5))
    t0, t1 = group.window

    per = group.result_by_device()
    total = group.result().joules
    assert sum(r.joules for r in per) == total  # same sums, same order
    edges = [t0] + sorted(float(e) for e in rng.uniform(t0, t1, cuts)) + [t1]
    tiled = sum(group.joules_between(a, b) for a, b in zip(edges, edges[1:]))
    assert tiled == pytest.approx(total, rel=1e-9, abs=1e-12)
    for d, r in enumerate(per):
        dev_tiled = sum(group.joules_between_by_device(a, b)[d]
                        for a, b in zip(edges, edges[1:]))
        assert dev_tiled == pytest.approx(r.joules, rel=1e-9, abs=1e-12)


# -- checkpoint: roundtrip arbitrary nested trees -------------------------------

@given(seed=st.integers(0, 2**16), depth=st.integers(1, 3))
@settings(max_examples=10, deadline=None)
def test_checkpoint_roundtrip_property(seed, depth, tmp_path_factory):
    from repro.training import checkpoint as ckpt

    rng = np.random.default_rng(seed)

    def make(d):
        if d == 0:
            return rng.standard_normal((rng.integers(1, 5),
                                        rng.integers(1, 5))).astype(np.float32)
        return {f"k{i}": make(d - 1) for i in range(rng.integers(1, 3))}

    tree = make(depth)
    path = tmp_path_factory.mktemp(f"ck{seed}")
    ckpt.save(str(path), 1, tree)
    restored, _ = ckpt.restore(str(path), tree)
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
